//! Real-time serving: the coordinator (ingest shards + model-worker
//! pool + rank shards) driving actual backend execution under
//! wall-clock time — the end-to-end (e) configuration of §5.1, with
//! Python entirely out of the request path.
//!
//! Two backend kinds:
//! * **Sleep** — delay-injection from ℓ(b), the paper's own emulation
//!   methodology, one worker thread per GPU;
//! * **Pjrt** — the real TinyCNN executables compiled from the JAX/
//!   Pallas artifacts. `PjRtClient` is `Rc`-based (not `Send`), so a
//!   single executor thread owns the runtime and serializes executions —
//!   on a CPU backend the "GPUs" share the same silicon anyway.
//!
//! With [`ServeConfig::autoscale`] set, a §3.5 epoch loop runs beside
//! the load: a collector thread folds the completion stream into
//! windowed counters, each epoch becomes a [`WindowStats`], the
//! [`AutoscaleController`] advises, and a [`LiveAutoscaler`] acts on
//! the running cluster — draining the highest GPU ids when idle
//! (backend worker kept alive but never granted again) and attaching
//! detached ids (spawning their backend worker on first attach) when
//! the bad rate climbs. The per-epoch timeline lands in
//! [`ServeReport::timeline`].

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::error::{Context, Result};

use crate::autoscale::live::{GpuState, LiveAutoscaler};
use crate::autoscale::{AutoscaleConfig, AutoscaleController, WindowStats};
use crate::coordinator::{Completion, CoordObs, Coordinator, CoordinatorConfig, ToBackend};
use crate::net::client::{DisconnectBreakdown, ReconnectPolicy};
use crate::net::faults::FaultPlan;
use crate::core::profile::{LatencyProfile, ModelSpec};
use crate::core::time::Micros;
use crate::core::types::GpuId;
use crate::metrics::EpochPoint;
use crate::obs::http;
use crate::obs::prom::Prom;
use crate::obs::trace::{self, HopStat, Stage};
use crate::runtime::{ModelRuntime, IMAGE_CHANNELS, IMAGE_DIM};
use crate::util::rng::Rng;
use crate::util::stats::{percentile, Histogram};
use crate::workload::{ArrivalKind, ArrivalStream};
use crate::{log_error, log_info};

/// Which execution substrate backs the GPUs.
pub enum BackendKind {
    /// Sleep ℓ(b) per batch (per-GPU worker threads).
    Sleep,
    /// Execute the AOT-compiled TinyCNN via PJRT (single executor
    /// thread owning the runtime; loads from this directory).
    Pjrt { artifacts_dir: PathBuf },
}

/// Serving experiment configuration.
pub struct ServeConfig {
    pub models: Vec<ModelSpec>,
    /// Total GPU capacity (backend channels / shard slots).
    pub num_gpus: usize,
    /// GPUs attached at start (`None` = all). The rest are autoscaler
    /// headroom: detached until an `Allocate` epoch attaches them.
    pub initial_gpus: Option<usize>,
    /// Rank shards in the coordinator (1 = the paper's single
    /// RankThread; clamped to `num_gpus`).
    pub rank_shards: usize,
    /// Frontend ingest shards (clamped to ≥ 1): the open-loop generator
    /// submits through an `IngestHandle`, batching arrivals that are
    /// due together into one producer-side send.
    pub ingest_shards: usize,
    /// Model-worker threads multiplexing per-model scheduling state
    /// (`None` = `min(models, available_parallelism)`).
    pub model_workers: Option<usize>,
    /// Remote rank tier: `symphony rank-server` addresses whose GPU
    /// ranges tile `0..num_gpus` in order (empty = in-process rank
    /// shards per `rank_shards`, which is then the only configuration
    /// that reads `rank_shards`). Backends stay in this process either
    /// way — only the batch-rate matchmaking crosses the wire.
    pub remote_ranks: Vec<String>,
    /// Aggregate offered rate, requests/second (used when
    /// `rate_phases` is empty).
    pub total_rate: f64,
    /// Piecewise offered-rate schedule: `(seconds, requests/second)`
    /// phases played in order — the Fig 15-style changing workload.
    /// Empty = constant `total_rate` for the whole run.
    pub rate_phases: Vec<(f64, f64)>,
    pub duration: Duration,
    pub backend: BackendKind,
    /// Run the §3.5 epoch loop against the live cluster.
    pub autoscale: Option<AutoscaleConfig>,
    /// Busy-poll the coordinator's ring inboxes (spin instead of
    /// parking when idle) — trades a core per consumer for the lowest
    /// hop latency. See `--busy-poll`.
    pub busy_poll: bool,
    /// Pin ingest shards, model workers, and rank shards to distinct
    /// cores (NUMA-node order). See `--pin-cores`; no-op off Linux.
    pub pin_cores: bool,
    pub seed: u64,
    /// Deterministic client-side wire fault injection under
    /// `--remote-ranks` ([`FaultPlan::parse`] grammar; `--fault-plan`
    /// on the CLI). [`FaultPlan::none`] injects nothing. Sessions
    /// killed by the plan recover through the reconnect machinery, so
    /// a faulted run still completes — that is the point.
    pub fault_plan: Arc<FaultPlan>,
    /// Flight-recorder sampling interval: trace 1 request in
    /// `trace_sample` (rounded up to a power of two). 0 disables
    /// tracing — unless `trace_out` is set, which implies a default
    /// interval. See `--trace-sample`.
    pub trace_sample: u64,
    /// Dump the recorded spans as Chrome trace-event JSON here
    /// (Perfetto / `chrome://tracing`). See `--trace-out`.
    pub trace_out: Option<PathBuf>,
    /// Serve Prometheus text exposition on this address for the
    /// duration of the run (`--metrics-listen ADDR`); `None` runs no
    /// listener.
    pub metrics_listen: Option<String>,
}

/// What a serving run reports.
#[derive(Debug)]
pub struct ServeReport {
    pub submitted: u64,
    pub completed: u64,
    pub dropped: u64,
    pub violations: u64,
    pub goodput: f64,
    pub p50_latency_ms: f64,
    pub p99_latency_ms: f64,
    pub median_batch: usize,
    pub mean_batch: f64,
    pub batches: u64,
    pub wall_secs: f64,
    /// Rank-tier grants over the run.
    pub grants: u64,
    /// Overflow-routed candidates that landed on a shard with no free
    /// GPU (stale steering hint) — the ROADMAP's mis-steer rate.
    pub mis_steers: u64,
    /// Submissions that could not be delivered to a model worker (the
    /// seed silently swallowed these `SendError`s).
    pub dropped_submits: u64,
    /// Remote rank-server sessions that ended without this process
    /// asking (always 0 with an in-process rank tier) — a disconnect
    /// is counted and logged, never silently wedged through.
    pub rank_disconnects: u64,
    /// `rank_disconnects` split by cause (io / protocol / handshake /
    /// backlog-overflow).
    pub rank_disconnect_causes: DisconnectBreakdown,
    /// Sessions re-established by the reconnect state machine. A chaos
    /// run that kills K sessions should end with `rank_disconnects ==
    /// K` and `rank_reconnects == K` (every death recovered).
    pub rank_reconnects: u64,
    /// Stale-session down-frames dropped by the epoch fence.
    pub rank_fenced_frames: u64,
    /// Per-epoch autoscale timeline (empty without `autoscale`).
    pub timeline: Vec<EpochPoint>,
    /// Per-hop p50/p99 latency rows from the flight recorder, in
    /// pipeline order (empty when tracing was off).
    pub hop_breakdown: Vec<HopStat>,
    /// Sampled trace events shed by the recorder's bounded ring (0
    /// when tracing was off — shedding loses spans, never requests).
    pub trace_shed: u64,
    /// Ring occupancy high-watermarks per tier (max slots ever
    /// occupied across that tier's rings) — the "how close to
    /// backpressure did this run get" gauge.
    pub ingest_ring_hwm: u64,
    pub model_ring_hwm: u64,
    /// 0 with a remote rank tier (the rings live in the rank server).
    pub rank_ring_hwm: u64,
}

impl ServeReport {
    pub fn bad_fraction(&self) -> f64 {
        let finished = self.completed + self.dropped;
        if finished == 0 {
            0.0
        } else {
            (self.dropped + self.violations) as f64 / finished as f64
        }
    }
}

/// Windowed counters shared between the completion collector and the
/// autoscale epoch loop (the §3.5 stats pipeline: completion stream →
/// `WindowStats` per epoch).
#[derive(Default)]
struct LiveCounts {
    /// Requests completed within their SLO.
    good: u64,
    /// Requests completed late or dropped.
    bad: u64,
    /// Cumulative per-GPU execution busy time, µs.
    busy_us: Vec<u64>,
}

/// Everything the collector accumulated for the final report.
struct CollectorOut {
    latencies: Vec<f64>,
    batch_hist: Histogram,
    completed: u64,
    dropped: u64,
    violations: u64,
    batches: u64,
    first: Micros,
    last: Micros,
}

/// Per-GPU sleep workers with deferred spawn: workers for initially
/// detached GPUs start only when the autoscaler first attaches them
/// (the §3.5 add path: spawn the backend worker, then the shard-side
/// `Attach` makes the GPU grantable).
struct SleepWorkers {
    rxs: Mutex<Vec<Option<Receiver<ToBackend>>>>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Held for deferred spawns; `close()` releases it so the
    /// completion channel can disconnect once the spawned workers exit
    /// (otherwise the collector only ever exits via its idle timeout).
    comp: Mutex<Option<Sender<Completion>>>,
    profiles: Vec<LatencyProfile>,
}

impl SleepWorkers {
    /// Spawn the worker for `gpu` if it has not been spawned yet.
    fn ensure_spawned(&self, gpu: GpuId) {
        let rx = self.rxs.lock().unwrap()[gpu.0 as usize].take();
        if let Some(rx) = rx {
            let Some(comp) = self.comp.lock().unwrap().clone() else {
                return; // shutting down; nothing left to serve
            };
            let profiles = self.profiles.clone();
            let h = std::thread::spawn(move || sleep_worker(gpu, rx, comp, profiles));
            self.handles.lock().unwrap().push(h);
        }
    }

    /// Drop the retained completion sender (no more deferred spawns).
    fn close(&self) {
        self.comp.lock().unwrap().take();
    }

    fn join_all(&self) {
        let handles: Vec<_> = self.handles.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        // Receivers of never-spawned workers drop here, closing their
        // channels.
        self.rxs.lock().unwrap().clear();
    }
}

/// Run a serving experiment end to end.
pub fn serve(cfg: ServeConfig) -> Result<ServeReport> {
    // Flight recorder first, so taps are live before the first submit.
    // `--trace-out` without an explicit interval gets a default that
    // keeps the recorder well under its shed threshold at high rates.
    let sample = if cfg.trace_sample > 0 {
        cfg.trace_sample
    } else if cfg.trace_out.is_some() {
        64
    } else {
        0
    };
    let trace_session = if sample > 0 { trace::install(sample) } else { None };

    let (comp_tx, comp_rx) = channel::<Completion>();
    let initial_gpus = cfg.initial_gpus.unwrap_or(cfg.num_gpus).min(cfg.num_gpus);

    // Backend channels (one per GPU).
    let mut backend_txs = Vec::new();
    let mut pjrt_handles = Vec::new();
    let mut sleep_workers: Option<Arc<SleepWorkers>> = None;
    match &cfg.backend {
        BackendKind::Sleep => {
            let mut rxs = Vec::new();
            for _ in 0..cfg.num_gpus {
                let (tx, rx) = channel::<ToBackend>();
                backend_txs.push(tx);
                rxs.push(Some(rx));
            }
            let workers = Arc::new(SleepWorkers {
                rxs: Mutex::new(rxs),
                handles: Mutex::new(Vec::new()),
                comp: Mutex::new(Some(comp_tx.clone())),
                profiles: cfg.models.iter().map(|m| m.profile).collect(),
            });
            for g in 0..initial_gpus {
                workers.ensure_spawned(GpuId(g as u32));
            }
            sleep_workers = Some(workers);
        }
        BackendKind::Pjrt { artifacts_dir } => {
            // One executor thread owns the (non-Send) PJRT runtime; all
            // GPU channels funnel into it (spawned upfront — the funnel
            // threads are free, the runtime is shared anyway).
            let (job_tx, job_rx) = channel::<(GpuId, ToBackend)>();
            for g in 0..cfg.num_gpus {
                let (tx, rx) = channel::<ToBackend>();
                backend_txs.push(tx);
                let jt = job_tx.clone();
                pjrt_handles.push(std::thread::spawn(move || {
                    for msg in rx {
                        let stop = matches!(msg, ToBackend::Shutdown);
                        let _ = jt.send((GpuId(g as u32), msg));
                        if stop {
                            break;
                        }
                    }
                }));
            }
            drop(job_tx);
            let dir = artifacts_dir.clone();
            let comp = comp_tx.clone();
            let gpus = cfg.num_gpus;
            pjrt_handles.push(std::thread::spawn(move || {
                pjrt_executor(dir, job_rx, comp, gpus)
            }));
        }
    }

    let coord = Coordinator::try_spawn(
        CoordinatorConfig {
            profiles: cfg.models.iter().map(|m| m.profile).collect(),
            num_gpus: cfg.num_gpus,
            initial_gpus: cfg.initial_gpus,
            rank_shards: cfg.rank_shards,
            ingest_shards: cfg.ingest_shards,
            model_workers: cfg.model_workers,
            // The paper budgets the RDMA p99.99 (33 µs) here; without a
            // kernel-bypass control plane we budget OS-thread wakeup +
            // channel jitter instead (§4.3's predictability argument,
            // measured in EXPERIMENTS.md). The same budget absorbs the
            // wire's handshake clock-sync error under --remote-ranks.
            net_bound: Micros::from_millis_f64(2.0),
            exec_margin: Micros::from_millis_f64(0.5),
            remote_ranks: cfg.remote_ranks.clone(),
            busy_poll: cfg.busy_poll,
            pin_cores: cfg.pin_cores,
            reconnect: ReconnectPolicy::default(),
            fault_plan: cfg.fault_plan.clone(),
        },
        backend_txs.clone(),
        comp_tx.clone(),
    )?;
    let clock = coord.clock;
    let depth_probe = coord.queue_depth_probe();

    // Completion collector: final-report accumulation plus the shared
    // windowed counters the autoscale loop reads.
    let counts = Arc::new(Mutex::new(LiveCounts {
        busy_us: vec![0; cfg.num_gpus],
        ..Default::default()
    }));
    let collector = {
        let counts = counts.clone();
        std::thread::spawn(move || collect(comp_rx, counts))
    };

    // Scrape-visible run gauges the epoch loop keeps fresh (and that
    // hold their initial values on non-autoscale runs).
    let gpus_active = Arc::new(AtomicU64::new(initial_gpus as u64));
    let autoscale_epochs = Arc::new(AtomicU64::new(0));

    // The `/metrics` listener lives exactly as long as this run:
    // dropping the guard at return unblocks its thread.
    let _metrics_srv = match &cfg.metrics_listen {
        Some(addr) => {
            let obs = coord.observe();
            let counts = counts.clone();
            let ga = gpus_active.clone();
            let ae = autoscale_epochs.clone();
            let srv = http::spawn(
                addr,
                Arc::new(move || render_metrics(&counts, &obs, &ga, &ae)),
            )
            .with_context(|| format!("binding metrics listener on {addr}"))?;
            log_info!("serve: metrics on http://{}/metrics", srv.addr());
            Some(srv)
        }
        None => None,
    };

    // Autoscale epoch loop (§3.5 live wiring).
    let (stop_tx, stop_rx) = channel::<()>();
    let scaler_handle = cfg.autoscale.map(|as_cfg| {
        let ctl = AutoscaleController::new(as_cfg);
        let mut scaler = LiveAutoscaler::new(ctl, coord.cluster_ctl(), initial_gpus);
        let counts = counts.clone();
        let workers = sleep_workers.clone();
        let depth_probe = depth_probe.clone();
        let gpus_active = gpus_active.clone();
        let autoscale_epochs = autoscale_epochs.clone();
        let epoch = Duration::from_micros(as_cfg.epoch.0.max(1));
        std::thread::spawn(move || {
            let mut log: Vec<EpochPoint> = Vec::new();
            let mut last: (u64, u64, Vec<u64>) = (0, 0, Vec::new());
            let mut last_t = clock.now();
            loop {
                // On stop, fold the final partial window into the
                // timeline (no scaling action) so the last logged
                // point reflects the cluster state at shutdown.
                let stopping = match stop_rx.recv_timeout(epoch) {
                    Err(RecvTimeoutError::Timeout) => false,
                    Ok(()) | Err(RecvTimeoutError::Disconnected) => true,
                };
                let now = clock.now();
                let (good, bad, busy) = {
                    let c = counts.lock().unwrap();
                    (c.good, c.bad, c.busy_us.clone())
                };
                let window_s = (now.saturating_sub(last_t)).as_secs_f64().max(1e-9);
                let active = scaler.active_gpus();
                let dgood = good - last.0;
                let dbad = bad - last.1;
                let dbusy_us: u64 = busy
                    .iter()
                    .enumerate()
                    .map(|(g, &b)| b - last.2.get(g).copied().unwrap_or(0))
                    .sum();
                let w = WindowStats {
                    good: dgood,
                    bad: dbad,
                    busy_fraction: if active > 0 {
                        ((dbusy_us as f64 / 1e6) / (window_s * active as f64)).min(1.0)
                    } else {
                        0.0
                    },
                    active_gpus: active,
                    // Live backlog at epoch end: lets the controller
                    // distinguish "idle" from "stalling" (few
                    // completions because everything is still queued).
                    queue_depth: depth_probe.total(),
                };
                let before: Vec<GpuState> = scaler.gpu_states().to_vec();
                let delta = if stopping { 0 } else { scaler.step(&w) };
                // The add path spawns the backend worker for every GPU
                // attached this epoch (sleep backend only; PJRT funnels
                // exist upfront).
                if let Some(workers) = &workers {
                    for (g, prev) in before.iter().enumerate() {
                        if *prev != GpuState::Attached
                            && scaler.gpu_states()[g] == GpuState::Attached
                        {
                            workers.ensure_spawned(GpuId(g as u32));
                        }
                    }
                }
                log.push(EpochPoint {
                    t_s: now.as_secs_f64(),
                    offered_rps: (dgood + dbad) as f64 / window_s,
                    active_gpus: scaler.active_gpus(),
                    bad_rate: w.bad_rate(),
                    busy_fraction: w.busy_fraction,
                    delta,
                });
                // relaxed: advisory scrape gauges, refreshed per epoch.
                gpus_active.store(scaler.active_gpus() as u64, Ordering::Relaxed);
                autoscale_epochs.fetch_add(1, Ordering::Relaxed);
                last = (good, bad, busy);
                last_t = now;
                if stopping {
                    break;
                }
            }
            log
        })
    });

    // Load generator: merged (piecewise-)Poisson streams on the
    // coordinator clock.
    let mut rng = Rng::new(cfg.seed);
    let n_models = cfg.models.len();
    let phases: Vec<(f64, f64)> = if cfg.rate_phases.is_empty() {
        vec![(cfg.duration.as_secs_f64(), cfg.total_rate)]
    } else {
        cfg.rate_phases.clone()
    };
    let segments: Vec<(Micros, f64)> = {
        let mut t = 0.0;
        let mut segs = Vec::new();
        for &(secs, rate) in &phases {
            segs.push((Micros::from_secs_f64(t), rate / n_models as f64));
            t += secs;
        }
        segs
    };
    let mut streams: Vec<ArrivalStream> = (0..n_models)
        .map(|i| {
            ArrivalStream::new(
                ArrivalKind::PiecewiseRate {
                    segments: segments.clone(),
                    shape: 1.0,
                },
                rng.fork(i as u64),
            )
        })
        .collect();
    let mut next: Vec<Option<Micros>> =
        streams.iter_mut().map(|s| s.next_after(Micros::ZERO)).collect();
    let horizon = Micros(cfg.duration.as_micros() as u64);
    let mut submitted = 0u64;
    // Earliest pending arrival across models.
    let earliest = |next: &[Option<Micros>]| -> Option<(usize, Micros)> {
        next.iter()
            .enumerate()
            .filter_map(|(i, t)| t.map(|t| (i, t)))
            .min_by_key(|&(_, t)| t)
    };
    // The generator submits through an ingest handle: arrivals that are
    // due together (the generator woke late, or the offered rate
    // outruns one wakeup per request) leave as ONE producer-side batch
    // instead of one channel send each — under overload the open loop
    // no longer serializes on per-request submission.
    let ingest = coord.ingest_handle();
    let mut pending: Vec<crate::core::types::Request> = Vec::new();
    loop {
        let Some((mi, t)) = earliest(&next) else {
            // All streams exhausted (e.g. a trailing zero-rate phase):
            // idle out the configured duration so the autoscale epoch
            // loop keeps observing — and logging — the trough.
            std::thread::sleep(clock.until(horizon));
            break;
        };
        if t > horizon {
            break;
        }
        let wait = clock.until(t);
        if !wait.is_zero() {
            std::thread::sleep(wait);
        }
        let now = clock.now();
        pending.clear();
        let (mut mi, mut t) = (mi, t);
        loop {
            pending.push(crate::core::types::Request {
                id: crate::core::types::RequestId(submitted),
                model: crate::core::types::ModelId(mi as u32),
                arrival: now,
                deadline: t + cfg.models[mi].slo,
            });
            submitted += 1;
            next[mi] = streams[mi].next_after(t);
            match earliest(&next) {
                // Gather everything already due; future arrivals wait
                // for their own wakeup.
                Some((m2, t2)) if t2 <= now && t2 <= horizon => {
                    mi = m2;
                    t = t2;
                }
                _ => break,
            }
        }
        ingest.submit_batch(&pending);
    }

    // Drain: let in-flight work land, then stop the epoch loop and the
    // coordinator.
    std::thread::sleep(Duration::from_millis(300));
    let timeline = match scaler_handle {
        Some(h) => {
            let _ = stop_tx.send(());
            h.join().unwrap_or_default()
        }
        None => Vec::new(),
    };
    let (front_stats, shard_stats) = coord.shutdown_stats();
    for tx in &backend_txs {
        let _ = tx.send(ToBackend::Shutdown);
    }

    // Collect completions. Every retained completion sender must go
    // before the join: the epoch thread is down, `close()` drops the
    // deferred-spawn sender, and the workers drop theirs as they
    // process Shutdown — so the collector exits on disconnect instead
    // of idling out.
    drop(comp_tx);
    if let Some(workers) = &sleep_workers {
        workers.close();
    }
    let out = collector.join().expect("collector thread");
    if let Some(workers) = &sleep_workers {
        workers.join_all();
    }
    for h in pjrt_handles {
        let _ = h.join();
    }

    // Tear down the recorder last: the collector (Complete taps) is
    // joined, so the dump holds every sampled span of the run.
    let (hop_breakdown, trace_shed) = match trace_session {
        Some(session) => {
            let dump = session.finish();
            if let Some(path) = &cfg.trace_out {
                match dump.write_chrome_trace(path) {
                    Ok(()) => log_info!(
                        "serve: wrote {} trace events to {}",
                        dump.events.len(),
                        path.display()
                    ),
                    Err(e) => log_error!("serve: writing trace to {}: {e}", path.display()),
                }
            }
            (dump.hop_breakdown(), dump.shed)
        }
        None => (Vec::new(), 0),
    };

    let wall_secs = (out.last.saturating_sub(out.first)).as_secs_f64().max(1e-9);
    let good = out.completed - out.violations;
    Ok(ServeReport {
        submitted,
        completed: out.completed,
        dropped: out.dropped,
        violations: out.violations,
        goodput: good as f64 / wall_secs,
        p50_latency_ms: percentile(&out.latencies, 50.0),
        p99_latency_ms: percentile(&out.latencies, 99.0),
        median_batch: out.batch_hist.median(),
        mean_batch: out.batch_hist.mean(),
        batches: out.batches,
        wall_secs,
        grants: shard_stats.grants,
        mis_steers: shard_stats.mis_steers,
        dropped_submits: front_stats.dropped_submits,
        rank_disconnects: front_stats.rank_disconnects,
        rank_disconnect_causes: front_stats.rank_disconnect_causes,
        rank_reconnects: front_stats.rank_reconnects,
        rank_fenced_frames: front_stats.rank_fenced_frames,
        timeline,
        hop_breakdown,
        trace_shed,
        ingest_ring_hwm: front_stats.ingest_ring_hwm,
        model_ring_hwm: front_stats.model_ring_hwm,
        rank_ring_hwm: front_stats.rank_ring_hwm,
    }
    .tap_duration(cfg.duration))
}

fn collect(comp_rx: Receiver<Completion>, counts: Arc<Mutex<LiveCounts>>) -> CollectorOut {
    let mut out = CollectorOut {
        latencies: Vec::new(),
        batch_hist: Histogram::new(),
        completed: 0,
        dropped: 0,
        violations: 0,
        batches: 0,
        first: Micros::MAX,
        last: Micros::ZERO,
    };
    loop {
        // The collector runs for the whole serve call, so a quiet
        // stretch (low offered rate, a zero-rate phase) must NOT end
        // collection — only channel disconnect does. The shutdown path
        // guarantees disconnect: `serve` drops its sender, the epoch
        // thread holds none, `SleepWorkers::close()` releases the
        // deferred-spawn clone, and workers/executors drop theirs as
        // they process Shutdown.
        let c = match comp_rx.recv_timeout(crate::coordinator::IDLE_RECV_TIMEOUT) {
            Ok(c) => c,
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => break,
        };
        match c {
            Completion::Batch {
                gpu,
                requests,
                start,
                end,
                ..
            } => {
                out.batches += 1;
                out.batch_hist.add_n(requests.len(), requests.len() as u64);
                out.first = out.first.min(start);
                out.last = out.last.max(end);
                let mut good = 0u64;
                let mut bad = 0u64;
                for r in &requests {
                    trace::req_event(Stage::Complete, r.id);
                    out.completed += 1;
                    out.latencies.push((end.saturating_sub(r.arrival)).as_millis_f64());
                    if end > r.deadline {
                        out.violations += 1;
                        bad += 1;
                    } else {
                        good += 1;
                    }
                }
                let mut c = counts.lock().unwrap();
                c.good += good;
                c.bad += bad;
                if let Some(b) = c.busy_us.get_mut(gpu.0 as usize) {
                    *b += end.saturating_sub(start).0;
                }
            }
            Completion::Dropped(rs) => {
                out.dropped += rs.len() as u64;
                counts.lock().unwrap().bad += rs.len() as u64;
            }
        }
    }
    out
}

/// One `/metrics` scrape: Prometheus 0.0.4 text exposition over the
/// run's live counters. Every value is a relaxed load or one short
/// mutex hold (the `LiveCounts` lock the collector already takes per
/// batch) — a scrape never touches the request path.
fn render_metrics(
    counts: &Mutex<LiveCounts>,
    obs: &CoordObs,
    gpus_active: &AtomicU64,
    autoscale_epochs: &AtomicU64,
) -> String {
    let (good, bad) = {
        let c = counts.lock().unwrap();
        (c.good, c.bad)
    };
    let mut p = Prom::new();
    p.family(
        "symphony_requests_good_total",
        "counter",
        "Requests completed within their SLO.",
    );
    p.sample("symphony_requests_good_total", &[], good);
    p.family(
        "symphony_requests_bad_total",
        "counter",
        "Requests completed late or dropped.",
    );
    p.sample("symphony_requests_bad_total", &[], bad);
    p.family(
        "symphony_dropped_submits_total",
        "counter",
        "Submissions that could not be delivered to a model worker.",
    );
    // relaxed: advisory scrape counter.
    p.sample(
        "symphony_dropped_submits_total",
        &[],
        obs.dropped_submits.load(Ordering::Relaxed),
    );

    p.family(
        "symphony_grants_total",
        "counter",
        "GPU grants issued by the rank tier.",
    );
    p.family(
        "symphony_mis_steers_total",
        "counter",
        "Overflow-routed candidates that landed on a shard with no free GPU.",
    );
    for (i, s) in obs.shard_live.iter().enumerate() {
        let idx = i.to_string();
        p.sample("symphony_grants_total", &[("shard", &idx)], s.grants());
        p.sample("symphony_mis_steers_total", &[("shard", &idx)], s.mis_steers());
    }
    // With a remote tier, grants are what the wire reader has decoded;
    // mis-steers stay server-side (scrape the rank server for them).
    for (i, r) in obs.remote.iter().enumerate() {
        let idx = format!("remote{i}");
        p.sample("symphony_grants_total", &[("shard", &idx)], r.grants());
    }

    p.family(
        "symphony_rank_disconnects_total",
        "counter",
        "Remote rank sessions that ended without this process asking, by cause.",
    );
    let d = &obs.disconnects;
    for (cause, n) in [
        ("io", d.io()),
        ("protocol", d.protocol()),
        ("handshake", d.handshake()),
        ("backlog-overflow", d.backlog_overflow()),
    ] {
        p.sample("symphony_rank_disconnects_total", &[("cause", cause)], n);
    }
    p.family(
        "symphony_rank_reconnects_total",
        "counter",
        "Remote rank sessions re-established by the reconnect state machine.",
    );
    p.sample(
        "symphony_rank_reconnects_total",
        &[],
        obs.remote.iter().map(|r| r.reconnects()).sum(),
    );
    p.family(
        "symphony_fenced_frames_total",
        "counter",
        "Stale-session down-frames dropped by the epoch fence.",
    );
    p.sample(
        "symphony_fenced_frames_total",
        &[],
        obs.remote.iter().map(|r| r.fenced()).sum(),
    );

    p.family(
        "symphony_queue_depth",
        "gauge",
        "Requests queued in model workers (admitted, not yet dispatched).",
    );
    p.sample("symphony_queue_depth", &[], obs.queue_depth.total());
    p.family(
        "symphony_ring_depth",
        "gauge",
        "Current occupancy of a pipeline ring.",
    );
    p.family(
        "symphony_ring_hwm",
        "gauge",
        "High-watermark occupancy of a pipeline ring.",
    );
    for (tier, probes) in [
        ("ingest", &obs.ingest_rings),
        ("model", &obs.model_rings),
        ("rank", &obs.rank_rings),
    ] {
        for (i, pr) in probes.iter().enumerate() {
            let idx = i.to_string();
            let labels = [("tier", tier), ("idx", idx.as_str())];
            p.sample("symphony_ring_depth", &labels, pr.depth() as u64);
            p.sample("symphony_ring_hwm", &labels, pr.high_watermark() as u64);
        }
    }

    p.family(
        "symphony_gpus_active",
        "gauge",
        "GPUs currently attached (tracks the autoscaler on autoscale runs).",
    );
    // relaxed: advisory scrape gauge.
    p.sample("symphony_gpus_active", &[], gpus_active.load(Ordering::Relaxed));
    p.family(
        "symphony_autoscale_epochs_total",
        "counter",
        "Autoscale epochs evaluated so far.",
    );
    p.sample(
        "symphony_autoscale_epochs_total",
        &[],
        autoscale_epochs.load(Ordering::Relaxed),
    );
    p.family(
        "symphony_trace_shed_total",
        "counter",
        "Sampled flight-recorder events shed (ring full or retained cap).",
    );
    p.sample("symphony_trace_shed_total", &[], trace::shed_count());
    p.finish()
}

impl ServeReport {
    fn tap_duration(mut self, d: Duration) -> Self {
        // Use at least the configured duration for goodput if execution
        // span was shorter (sparse workloads).
        let secs = d.as_secs_f64();
        if self.wall_secs < secs * 0.5 {
            let good = (self.completed - self.violations) as f64;
            self.goodput = good / secs;
            self.wall_secs = secs;
        }
        self
    }
}

/// Sleep-emulated GPU worker: the paper's delay-injection backend.
fn sleep_worker(
    gpu: GpuId,
    rx: Receiver<ToBackend>,
    comp: Sender<Completion>,
    profiles: Vec<crate::core::profile::LatencyProfile>,
) {
    let clock = crate::coordinator::Clock::new();
    for msg in rx {
        match msg {
            ToBackend::Execute {
                model,
                requests,
                dispatched_at,
            } => {
                let start = clock.now();
                let dur = profiles[model.0 as usize].latency(requests.len() as u32);
                std::thread::sleep(Duration::from_micros(dur.0));
                let end = clock.now();
                // Map start/end onto the request timeline: the sleep
                // worker's clock origin differs from the coordinator's;
                // approximate with dispatched_at + measured elapsed.
                let elapsed = end - start;
                let _ = comp.send(Completion::Batch {
                    gpu,
                    model,
                    requests,
                    dispatched_at,
                    start: dispatched_at,
                    end: dispatched_at + elapsed,
                });
            }
            ToBackend::Shutdown => break,
        }
    }
}

/// The single PJRT executor thread (owns the non-Send runtime).
fn pjrt_executor(
    dir: PathBuf,
    rx: Receiver<(GpuId, ToBackend)>,
    comp: Sender<Completion>,
    num_gpus: usize,
) {
    let rt = match ModelRuntime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            log_error!("pjrt executor: failed to load artifacts: {e:#}");
            return;
        }
    };
    let clock = crate::coordinator::Clock::new();
    let mut open = num_gpus;
    let input_len = IMAGE_DIM * IMAGE_DIM * IMAGE_CHANNELS;
    for (gpu, msg) in rx {
        match msg {
            ToBackend::Execute {
                model,
                requests,
                dispatched_at,
            } => {
                let n = requests.len() as u32;
                let inputs = vec![0.5f32; n as usize * input_len];
                let t0 = clock.now();
                let ok = rt.execute(n, &inputs).is_ok();
                let elapsed = clock.now().saturating_sub(t0);
                if ok {
                    let _ = comp.send(Completion::Batch {
                        gpu,
                        model,
                        requests,
                        dispatched_at,
                        start: dispatched_at,
                        end: dispatched_at + elapsed,
                    });
                } else {
                    let _ = comp.send(Completion::Dropped(requests));
                }
            }
            ToBackend::Shutdown => {
                open = open.saturating_sub(1);
                if open == 0 {
                    break;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sleep_serving_end_to_end() {
        // Small real-time run: 2 models, 2 emulated GPUs, 200 r/s for
        // half a second. Everything should complete within SLO.
        let models = vec![
            ModelSpec::new("a", 0.2, 2.0, 50.0),
            ModelSpec::new("b", 0.2, 2.0, 50.0),
        ];
        let report = serve(ServeConfig {
            models,
            num_gpus: 2,
            initial_gpus: None,
            rank_shards: 2,
            ingest_shards: 2,
            model_workers: None,
            remote_ranks: Vec::new(),
            total_rate: 200.0,
            rate_phases: Vec::new(),
            duration: Duration::from_millis(500),
            backend: BackendKind::Sleep,
            autoscale: None,
            busy_poll: false,
            pin_cores: false,
            seed: 5,
            fault_plan: FaultPlan::none(),
            trace_sample: 0,
            trace_out: None,
            metrics_listen: None,
        })
        .unwrap();
        assert!(report.submitted > 50, "submitted {}", report.submitted);
        let finished = report.completed + report.dropped;
        assert!(
            finished as f64 >= report.submitted as f64 * 0.9,
            "finished {finished} of {}",
            report.submitted
        );
        // Loose bound: wall-clock scheduling noise on a shared CI host.
        assert!(
            report.bad_fraction() < 0.15,
            "bad fraction {}",
            report.bad_fraction()
        );
        assert!(report.p99_latency_ms < 60.0, "p99 {}", report.p99_latency_ms);
        assert!(report.grants > 0);
        assert_eq!(report.dropped_submits, 0, "no submission may be lost");
        assert_eq!(report.rank_disconnects, 0, "in-process tier never disconnects");
        assert!(report.timeline.is_empty(), "no autoscale, no timeline");
    }

    /// The §3.5 live wiring end to end: a low→high→low offered-rate
    /// schedule must make the attached-GPU count rise with the overload
    /// and fall back in the final trough (Fig 15's load-proportional
    /// shape), while every batch keeps landing on an attached GPU.
    #[test]
    fn autoscale_follows_offered_rate() {
        // ℓ(b) = 1.0·b + 5.0 ms: one GPU sustains ~700 r/s at deep
        // batches, so 2 GPUs saturate hard at 2600 r/s.
        let models = vec![ModelSpec::new("svc", 1.0, 5.0, 60.0)];
        let report = serve(ServeConfig {
            models,
            num_gpus: 6,
            initial_gpus: Some(2),
            rank_shards: 2,
            ingest_shards: 1,
            model_workers: None,
            remote_ranks: Vec::new(),
            total_rate: 0.0,
            rate_phases: vec![(1.0, 150.0), (2.0, 2600.0), (2.0, 120.0)],
            duration: Duration::from_secs_f64(5.0),
            backend: BackendKind::Sleep,
            autoscale: Some(AutoscaleConfig {
                bad_rate_threshold: 0.05,
                idle_threshold: 0.30,
                min_gpus: 1,
                max_gpus: 6,
                epoch: Micros::from_millis_f64(400.0),
                backlog_per_gpu: 4.0,
            }),
            busy_poll: false,
            pin_cores: false,
            seed: 11,
            fault_plan: FaultPlan::none(),
            trace_sample: 0,
            trace_out: None,
            metrics_listen: None,
        })
        .unwrap();
        let (first, peak, last) = crate::metrics::timeline_extent(&report.timeline)
            .expect("autoscale run must log epochs");
        assert!(
            peak > 2,
            "overload phase never grew the cluster: first={first} peak={peak} \
             last={last} timeline={:?}",
            report.timeline
        );
        assert!(
            last < peak,
            "final trough never shrank the cluster: peak={peak} last={last} \
             timeline={:?}",
            report.timeline
        );
        // The high phase must have actually been served by the grown
        // cluster (not just dropped wholesale).
        assert!(
            report.completed > report.dropped,
            "completed {} vs dropped {}",
            report.completed,
            report.dropped
        );
    }
}
