//! Real-time serving: the coordinator (ModelThreads + rank shards) driving
//! actual backend execution under wall-clock time — the end-to-end (e)
//! configuration of §5.1, with Python entirely out of the request path.
//!
//! Two backend kinds:
//! * **Sleep** — delay-injection from ℓ(b), the paper's own emulation
//!   methodology, one worker thread per GPU;
//! * **Pjrt** — the real TinyCNN executables compiled from the JAX/
//!   Pallas artifacts. `PjRtClient` is `Rc`-based (not `Send`), so a
//!   single executor thread owns the runtime and serializes executions —
//!   on a CPU backend the "GPUs" share the same silicon anyway.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Duration;

use crate::util::error::Result;

use crate::coordinator::{Completion, Coordinator, CoordinatorConfig, ToBackend};
use crate::core::profile::ModelSpec;
use crate::core::time::Micros;
use crate::core::types::GpuId;
use crate::runtime::{ModelRuntime, IMAGE_CHANNELS, IMAGE_DIM};
use crate::util::rng::Rng;
use crate::util::stats::{percentile, Histogram};
use crate::workload::{ArrivalKind, ArrivalStream};

/// Which execution substrate backs the GPUs.
pub enum BackendKind {
    /// Sleep ℓ(b) per batch (per-GPU worker threads).
    Sleep,
    /// Execute the AOT-compiled TinyCNN via PJRT (single executor
    /// thread owning the runtime; loads from this directory).
    Pjrt { artifacts_dir: PathBuf },
}

/// Serving experiment configuration.
pub struct ServeConfig {
    pub models: Vec<ModelSpec>,
    pub num_gpus: usize,
    /// Rank shards in the coordinator (1 = the paper's single
    /// RankThread; clamped to `num_gpus`).
    pub rank_shards: usize,
    /// Aggregate offered rate, requests/second.
    pub total_rate: f64,
    pub duration: Duration,
    pub backend: BackendKind,
    pub seed: u64,
}

/// What a serving run reports.
#[derive(Debug)]
pub struct ServeReport {
    pub submitted: u64,
    pub completed: u64,
    pub dropped: u64,
    pub violations: u64,
    pub goodput: f64,
    pub p50_latency_ms: f64,
    pub p99_latency_ms: f64,
    pub median_batch: usize,
    pub mean_batch: f64,
    pub batches: u64,
    pub wall_secs: f64,
}

impl ServeReport {
    pub fn bad_fraction(&self) -> f64 {
        let finished = self.completed + self.dropped;
        if finished == 0 {
            0.0
        } else {
            (self.dropped + self.violations) as f64 / finished as f64
        }
    }
}

/// Run a serving experiment end to end.
pub fn serve(cfg: ServeConfig) -> Result<ServeReport> {
    let (comp_tx, comp_rx) = channel::<Completion>();

    // Backend channels (one per GPU).
    let mut backend_txs = Vec::new();
    let mut worker_handles = Vec::new();
    match &cfg.backend {
        BackendKind::Sleep => {
            for g in 0..cfg.num_gpus {
                let (tx, rx) = channel::<ToBackend>();
                backend_txs.push(tx);
                let profiles: Vec<_> = cfg.models.iter().map(|m| m.profile).collect();
                let comp = comp_tx.clone();
                worker_handles.push(std::thread::spawn(move || {
                    sleep_worker(GpuId(g as u32), rx, comp, profiles)
                }));
            }
        }
        BackendKind::Pjrt { artifacts_dir } => {
            // One executor thread owns the (non-Send) PJRT runtime; all
            // GPU channels funnel into it.
            let (job_tx, job_rx) = channel::<(GpuId, ToBackend)>();
            for g in 0..cfg.num_gpus {
                let (tx, rx) = channel::<ToBackend>();
                backend_txs.push(tx);
                let jt = job_tx.clone();
                worker_handles.push(std::thread::spawn(move || {
                    for msg in rx {
                        let stop = matches!(msg, ToBackend::Shutdown);
                        let _ = jt.send((GpuId(g as u32), msg));
                        if stop {
                            break;
                        }
                    }
                }));
            }
            drop(job_tx);
            let dir = artifacts_dir.clone();
            let comp = comp_tx.clone();
            let gpus = cfg.num_gpus;
            worker_handles.push(std::thread::spawn(move || {
                pjrt_executor(dir, job_rx, comp, gpus)
            }));
        }
    }

    let coord = Coordinator::spawn(
        CoordinatorConfig {
            profiles: cfg.models.iter().map(|m| m.profile).collect(),
            num_gpus: cfg.num_gpus,
            rank_shards: cfg.rank_shards,
            // The paper budgets the RDMA p99.99 (33 µs) here; without a
            // kernel-bypass control plane we budget OS-thread wakeup +
            // channel jitter instead (§4.3's predictability argument,
            // measured in EXPERIMENTS.md).
            net_bound: Micros::from_millis_f64(2.0),
            exec_margin: Micros::from_millis_f64(0.5),
        },
        backend_txs.clone(),
        comp_tx.clone(),
    );
    drop(comp_tx);

    // Load generator: merged Poisson streams on the coordinator clock.
    let clock = coord.clock;
    let mut rng = Rng::new(cfg.seed);
    let n_models = cfg.models.len();
    let mut streams: Vec<ArrivalStream> = (0..n_models)
        .map(|i| {
            ArrivalStream::new(
                ArrivalKind::Poisson {
                    rate: cfg.total_rate / n_models as f64,
                },
                rng.fork(i as u64),
            )
        })
        .collect();
    let mut next: Vec<Option<Micros>> =
        streams.iter_mut().map(|s| s.next_after(Micros::ZERO)).collect();
    let horizon = Micros(cfg.duration.as_micros() as u64);
    let mut submitted = 0u64;
    loop {
        // Earliest pending arrival across models.
        let Some((mi, t)) = next
            .iter()
            .enumerate()
            .filter_map(|(i, t)| t.map(|t| (i, t)))
            .min_by_key(|&(_, t)| t)
        else {
            break;
        };
        if t > horizon {
            break;
        }
        let wait = clock.until(t);
        if !wait.is_zero() {
            std::thread::sleep(wait);
        }
        coord.submit(crate::core::types::Request {
            id: crate::core::types::RequestId(submitted),
            model: crate::core::types::ModelId(mi as u32),
            arrival: clock.now(),
            deadline: t + cfg.models[mi].slo,
        });
        submitted += 1;
        next[mi] = streams[mi].next_after(t);
    }

    // Drain: let in-flight work land, then shut down.
    std::thread::sleep(Duration::from_millis(300));
    let (_processed, _grants) = coord.shutdown();
    for tx in &backend_txs {
        let _ = tx.send(ToBackend::Shutdown);
    }

    // Collect completions.
    let report = collect(comp_rx, &cfg, submitted);
    for h in worker_handles {
        let _ = h.join();
    }
    Ok(report)
}

fn collect(comp_rx: Receiver<Completion>, cfg: &ServeConfig, submitted: u64) -> ServeReport {
    let mut latencies = Vec::new();
    let mut batch_hist = Histogram::new();
    let mut completed = 0u64;
    let mut dropped = 0u64;
    let mut violations = 0u64;
    let mut batches = 0u64;
    let mut first = Micros::MAX;
    let mut last = Micros::ZERO;
    while let Ok(c) = comp_rx.recv_timeout(Duration::from_millis(500)) {
        match c {
            Completion::Batch {
                requests,
                start,
                end,
                ..
            } => {
                batches += 1;
                batch_hist.add_n(requests.len(), requests.len() as u64);
                first = first.min(start);
                last = last.max(end);
                for r in requests {
                    completed += 1;
                    latencies.push((end.saturating_sub(r.arrival)).as_millis_f64());
                    if end > r.deadline {
                        violations += 1;
                    }
                }
            }
            Completion::Dropped(rs) => dropped += rs.len() as u64,
        }
    }
    let wall_secs = (last.saturating_sub(first)).as_secs_f64().max(1e-9);
    let good = completed - violations;
    ServeReport {
        submitted,
        completed,
        dropped,
        violations,
        goodput: good as f64 / wall_secs,
        p50_latency_ms: percentile(&latencies, 50.0),
        p99_latency_ms: percentile(&latencies, 99.0),
        median_batch: batch_hist.median(),
        mean_batch: batch_hist.mean(),
        batches,
        wall_secs,
    }
    .tap_duration(cfg.duration)
}

impl ServeReport {
    fn tap_duration(mut self, d: Duration) -> Self {
        // Use at least the configured duration for goodput if execution
        // span was shorter (sparse workloads).
        let secs = d.as_secs_f64();
        if self.wall_secs < secs * 0.5 {
            let good = (self.completed - self.violations) as f64;
            self.goodput = good / secs;
            self.wall_secs = secs;
        }
        self
    }
}

/// Sleep-emulated GPU worker: the paper's delay-injection backend.
fn sleep_worker(
    gpu: GpuId,
    rx: Receiver<ToBackend>,
    comp: Sender<Completion>,
    profiles: Vec<crate::core::profile::LatencyProfile>,
) {
    let clock = crate::coordinator::Clock::new();
    for msg in rx {
        match msg {
            ToBackend::Execute {
                model,
                requests,
                dispatched_at,
            } => {
                let start = clock.now();
                let dur = profiles[model.0 as usize].latency(requests.len() as u32);
                std::thread::sleep(Duration::from_micros(dur.0));
                let end = clock.now();
                // Map start/end onto the request timeline: the sleep
                // worker's clock origin differs from the coordinator's;
                // approximate with dispatched_at + measured elapsed.
                let elapsed = end - start;
                let _ = comp.send(Completion::Batch {
                    gpu,
                    model,
                    requests,
                    dispatched_at,
                    start: dispatched_at,
                    end: dispatched_at + elapsed,
                });
            }
            ToBackend::Shutdown => break,
        }
    }
}

/// The single PJRT executor thread (owns the non-Send runtime).
fn pjrt_executor(
    dir: PathBuf,
    rx: Receiver<(GpuId, ToBackend)>,
    comp: Sender<Completion>,
    num_gpus: usize,
) {
    let rt = match ModelRuntime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("pjrt executor: failed to load artifacts: {e:#}");
            return;
        }
    };
    let clock = crate::coordinator::Clock::new();
    let mut open = num_gpus;
    let input_len = IMAGE_DIM * IMAGE_DIM * IMAGE_CHANNELS;
    for (gpu, msg) in rx {
        match msg {
            ToBackend::Execute {
                model,
                requests,
                dispatched_at,
            } => {
                let n = requests.len() as u32;
                let inputs = vec![0.5f32; n as usize * input_len];
                let t0 = clock.now();
                let ok = rt.execute(n, &inputs).is_ok();
                let elapsed = clock.now() - t0;
                if ok {
                    let _ = comp.send(Completion::Batch {
                        gpu,
                        model,
                        requests,
                        dispatched_at,
                        start: dispatched_at,
                        end: dispatched_at + elapsed,
                    });
                } else {
                    let _ = comp.send(Completion::Dropped(requests));
                }
            }
            ToBackend::Shutdown => {
                open = open.saturating_sub(1);
                if open == 0 {
                    break;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sleep_serving_end_to_end() {
        // Small real-time run: 2 models, 2 emulated GPUs, 200 r/s for
        // half a second. Everything should complete within SLO.
        let models = vec![
            ModelSpec::new("a", 0.2, 2.0, 50.0),
            ModelSpec::new("b", 0.2, 2.0, 50.0),
        ];
        let report = serve(ServeConfig {
            models,
            num_gpus: 2,
            rank_shards: 2,
            total_rate: 200.0,
            duration: Duration::from_millis(500),
            backend: BackendKind::Sleep,
            seed: 5,
        })
        .unwrap();
        assert!(report.submitted > 50, "submitted {}", report.submitted);
        let finished = report.completed + report.dropped;
        assert!(
            finished as f64 >= report.submitted as f64 * 0.9,
            "finished {finished} of {}",
            report.submitted
        );
        // Loose bound: wall-clock scheduling noise on a shared CI host.
        assert!(
            report.bad_fraction() < 0.15,
            "bad fraction {}",
            report.bad_fraction()
        );
        assert!(report.p99_latency_ms < 60.0, "p99 {}", report.p99_latency_ms);
    }
}
