//! Discrete-event cluster engine.
//!
//! Binds a `Workload` (request stream), a `Scheduler` (policy under
//! test), a set of emulated GPUs (delay-injection execution from ℓ(b)
//! profiles — the paper's own end-to-end methodology, §5), and a
//! `NetworkModel`. Produces `Metrics`.
//!
//! The engine owns the virtual clock and timers; schedulers are pure
//! event handlers (see `scheduler::Scheduler`). Timer cancellation is
//! done lazily with generation counters so `SetTimer` is O(log n);
//! re-arming a timer at its unchanged deadline is skipped outright (the
//! pending heap entry already fires there), and the heap is compacted
//! when dead entries — superseded or canceled generations — outnumber
//! live ones (§Perf: `update_candidate` re-arms per-model timers on
//! every arrival, which used to leave a trail of dead heap entries).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use crate::core::profile::ModelSpec;
use crate::core::time::Micros;
use crate::core::types::{GpuId, ModelId, OutcomeKind, ReqList, Request, RequestId};
use crate::metrics::{Metrics, MetricsConfig};
use crate::scheduler::{Command, Scheduler, TimerKey};
use crate::sim::gpu::GpuState;
use crate::sim::network::NetworkModel;
use crate::util::rng::Rng;
use crate::workload::Workload;

/// Minimum dead-entry count before the event heap is compacted; below
/// this the dead entries are cheaper to pop lazily than to sweep.
const COMPACT_MIN_DEAD: usize = 64;

/// Active timers — (generation, armed deadline) with O(1) array lookup
/// for the hot keys (per-model and per-GPU timers); Custom keys fall
/// back to a map. Generation 0 = no timer armed. The deadline lets
/// `SetTimer` detect unchanged re-arms and skip the heap push.
struct TimerSlots {
    model: Vec<(u64, Micros)>,
    model_aux: Vec<(u64, Micros)>,
    gpu: Vec<(u64, Micros)>,
    custom: HashMap<u64, (u64, Micros)>,
}

const UNARMED: (u64, Micros) = (0, Micros::ZERO);

impl TimerSlots {
    fn new(n_models: usize, n_gpus: usize) -> Self {
        TimerSlots {
            model: vec![UNARMED; n_models],
            model_aux: vec![UNARMED; n_models],
            gpu: vec![UNARMED; n_gpus],
            custom: HashMap::new(),
        }
    }

    #[inline]
    fn slot(&mut self, key: TimerKey) -> &mut (u64, Micros) {
        match key {
            TimerKey::Model(m) => &mut self.model[m.0 as usize],
            TimerKey::ModelAux(m) => &mut self.model_aux[m.0 as usize],
            TimerKey::Gpu(g) => {
                let i = g.0 as usize;
                if i >= self.gpu.len() {
                    self.gpu.resize(i + 1, UNARMED);
                }
                &mut self.gpu[i]
            }
            TimerKey::Custom(c) => self.custom.entry(c).or_insert(UNARMED),
        }
    }

    #[inline]
    fn set(&mut self, key: TimerKey, gen: u64, at: Micros) {
        *self.slot(key) = (gen, at);
    }

    #[inline]
    fn clear(&mut self, key: TimerKey) {
        *self.slot(key) = UNARMED;
    }

    /// `(gen, at)` if a timer is armed for `key`.
    #[inline]
    fn armed(&mut self, key: TimerKey) -> Option<(u64, Micros)> {
        let s = *self.slot(key);
        if s.0 != 0 {
            Some(s)
        } else {
            None
        }
    }

    #[inline]
    fn matches(&mut self, key: TimerKey, gen: u64) -> bool {
        self.slot(key).0 == gen
    }

    /// Read-only liveness check for heap compaction.
    fn live(&self, key: TimerKey, gen: u64) -> bool {
        let s = match key {
            TimerKey::Model(m) => self.model.get(m.0 as usize),
            TimerKey::ModelAux(m) => self.model_aux.get(m.0 as usize),
            TimerKey::Gpu(g) => self.gpu.get(g.0 as usize),
            TimerKey::Custom(c) => self.custom.get(&c),
        };
        s.map_or(false, |&(g, _)| g == gen)
    }
}

/// Internal event queue entries, ordered by (time, sequence).
#[derive(Clone, Debug)]
enum Ev {
    Timer { key: TimerKey, gen: u64 },
    GpuDone { gpu: GpuId, epoch: u64 },
    /// Autoscaler / engine-driver callback hook.
    External { tag: u64 },
}

/// Request bookkeeping for metrics + preemption.
#[derive(Clone, Copy, Debug)]
struct ReqRecord {
    model: ModelId,
    arrival: Micros,
    deadline: Micros,
    state: ReqState,
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum ReqState {
    Queued,
    Running,
    Done,
    Dropped,
}

/// Configuration of one simulation run.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub num_gpus: usize,
    pub horizon: Micros,
    pub network: NetworkModel,
    pub metrics: MetricsConfig,
    pub seed: u64,
    /// Capture a per-batch execution trace (Fig 4/5 style).
    pub record_trace: bool,
}

impl SimConfig {
    pub fn new(num_gpus: usize, horizon: Micros) -> Self {
        SimConfig {
            num_gpus,
            horizon,
            network: NetworkModel::Ideal,
            metrics: MetricsConfig::default(),
            seed: 1,
            record_trace: false,
        }
    }

    pub fn network(mut self, n: NetworkModel) -> Self {
        self.network = n;
        self
    }

    pub fn warmup(mut self, w: Micros) -> Self {
        self.metrics.warmup = w;
        self
    }

    pub fn samples(mut self, on: bool) -> Self {
        self.metrics.record_samples = on;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn trace(mut self, on: bool) -> Self {
        self.record_trace = on;
        self
    }
}

/// One executed batch in the captured trace (Fig 4/5 rendering).
#[derive(Clone, Debug)]
pub struct TraceEntry {
    pub gpu: GpuId,
    pub model: ModelId,
    pub size: u32,
    pub start: Micros,
    pub end: Micros,
    pub preempted: bool,
}

/// External hooks the engine driver can use mid-run (autoscaling).
pub trait EngineDriver {
    /// Called when an `External { tag }` event fires. Returning a new
    /// time re-arms the hook.
    fn on_tick(&mut self, tag: u64, now: Micros, cluster: &mut ClusterOps) -> Option<Micros>;
}

/// No-op driver.
pub struct NoDriver;
impl EngineDriver for NoDriver {
    fn on_tick(&mut self, _: u64, _: Micros, _: &mut ClusterOps) -> Option<Micros> {
        None
    }
}

/// The mutable cluster surface exposed to drivers (autoscaler).
pub struct ClusterOps<'a> {
    pub gpus: &'a mut Vec<GpuState>,
    pub metrics: &'a Metrics,
    /// GPUs added this run (the scheduler is notified by the engine).
    pub added: Vec<GpuId>,
    pub removed: Vec<GpuId>,
}

impl<'a> ClusterOps<'a> {
    /// Add one GPU; returns its id.
    pub fn add_gpu(&mut self) -> GpuId {
        // Reuse a retired slot if any, else grow.
        for (i, g) in self.gpus.iter_mut().enumerate() {
            if g.retired {
                g.retired = false;
                let id = GpuId(i as u32);
                self.added.push(id);
                return id;
            }
        }
        let id = GpuId(self.gpus.len() as u32);
        self.gpus.push(GpuState::default());
        self.added.push(id);
        id
    }

    /// Retire an idle GPU (highest-id idle first is the caller's policy).
    /// Returns false if the GPU is busy.
    pub fn remove_gpu(&mut self, id: GpuId) -> bool {
        let g = &mut self.gpus[id.0 as usize];
        if g.is_busy() || g.retired {
            return false;
        }
        g.retired = true;
        self.removed.push(id);
        true
    }

    pub fn active_gpus(&self) -> usize {
        self.gpus.iter().filter(|g| !g.retired).count()
    }
}

/// The discrete-event simulator.
pub struct Engine<S: Scheduler, D: EngineDriver = NoDriver> {
    pub scheduler: S,
    pub driver: D,
    workload: Workload,
    cfg: SimConfig,
    gpus: Vec<GpuState>,
    events: BinaryHeap<Reverse<(Micros, u64, usize)>>,
    ev_payload: Vec<Option<Ev>>,
    ev_free: Vec<usize>,
    seq: u64,
    timers: TimerSlots,
    timer_gen: u64,
    requests: Vec<ReqRecord>,
    req_base: u64,
    metrics: Metrics,
    rng: Rng,
    now: Micros,
    pending_req: Option<Request>,
    cmd_queue: Vec<Command>,
    pub trace: Vec<TraceEntry>,
    events_processed: u64,
    /// Heap entries whose timer generation was superseded or canceled;
    /// drives the compaction trigger.
    dead_timers: usize,
}

impl<S: Scheduler> Engine<S, NoDriver> {
    pub fn new(workload: Workload, scheduler: S, cfg: SimConfig) -> Self {
        Engine::with_driver(workload, scheduler, NoDriver, cfg)
    }
}

impl<S: Scheduler, D: EngineDriver> Engine<S, D> {
    pub fn with_driver(workload: Workload, scheduler: S, driver: D, cfg: SimConfig) -> Self {
        let models = workload.models.len();
        let metrics = Metrics::new(models, cfg.metrics);
        Engine {
            scheduler,
            driver,
            workload,
            gpus: (0..cfg.num_gpus).map(|_| GpuState::default()).collect(),
            events: BinaryHeap::new(),
            ev_payload: Vec::new(),
            ev_free: Vec::new(),
            seq: 0,
            timers: TimerSlots::new(models, cfg.num_gpus),
            timer_gen: 0,
            requests: Vec::new(),
            req_base: 0,
            metrics,
            rng: Rng::new(cfg.seed ^ 0x5173_09AD),
            now: Micros::ZERO,
            pending_req: None,
            cmd_queue: Vec::new(),
            trace: Vec::new(),
            cfg,
            events_processed: 0,
            dead_timers: 0,
        }
    }

    /// Arm an external (driver) hook at `at`.
    pub fn arm_external(&mut self, tag: u64, at: Micros) {
        self.push_event(at, Ev::External { tag });
    }

    fn push_event(&mut self, at: Micros, ev: Ev) {
        let slot = if let Some(i) = self.ev_free.pop() {
            self.ev_payload[i] = Some(ev);
            i
        } else {
            self.ev_payload.push(Some(ev));
            self.ev_payload.len() - 1
        };
        self.seq += 1;
        self.events.push(Reverse((at, self.seq, slot)));
    }

    fn pop_event(&mut self) -> Option<(Micros, Ev)> {
        let Reverse((at, _, slot)) = self.events.pop()?;
        let ev = self.ev_payload[slot].take().expect("event slot empty");
        self.ev_free.push(slot);
        Some((at, ev))
    }

    #[inline]
    fn req(&self, id: RequestId) -> &ReqRecord {
        &self.requests[(id.0 - self.req_base) as usize]
    }

    #[inline]
    fn req_mut(&mut self, id: RequestId) -> &mut ReqRecord {
        &mut self.requests[(id.0 - self.req_base) as usize]
    }

    fn model_spec(&self, m: ModelId) -> &ModelSpec {
        &self.workload.models[m.0 as usize]
    }

    /// Run to the horizon.
    pub fn run(mut self) -> SimResult<S, D> {
        loop {
            // Sweep dead timer entries once they dominate the heap.
            if self.dead_timers > COMPACT_MIN_DEAD && self.dead_timers * 2 > self.events.len() {
                self.compact_events();
            }

            // Pull the next arrival lazily so the heap stays small.
            if self.pending_req.is_none() {
                if let Some(r) = self.workload.next_request() {
                    if r.arrival <= self.cfg.horizon {
                        self.pending_req = Some(r);
                    }
                    // Requests past the horizon are discarded unrecorded.
                }
            }

            let next_ev_t = self.events.peek().map(|Reverse((t, _, _))| *t);
            let next_arr_t = self.pending_req.as_ref().map(|r| r.arrival);

            let (t, is_arrival) = match (next_ev_t, next_arr_t) {
                (None, None) => break,
                (Some(e), None) => (e, false),
                (None, Some(a)) => (a, true),
                (Some(e), Some(a)) => {
                    if a <= e {
                        (a, true)
                    } else {
                        (e, false)
                    }
                }
            };
            if t > self.cfg.horizon {
                break;
            }
            self.now = t;
            self.events_processed += 1;

            if is_arrival {
                let r = self.pending_req.take().unwrap();
                self.track_request(r);
                let mut cmds = std::mem::take(&mut self.cmd_queue);
                cmds.clear();
                self.scheduler.on_request(r, self.now, &mut cmds);
                self.apply_commands(cmds);
            } else {
                let (at, ev) = self.pop_event().unwrap();
                debug_assert_eq!(at, self.now);
                self.handle_event(ev);
            }
        }
        self.finalize()
    }

    fn track_request(&mut self, r: Request) {
        let idx = (r.id.0 - self.req_base) as usize;
        debug_assert_eq!(idx, self.requests.len(), "request ids must be sequential");
        self.requests.push(ReqRecord {
            model: r.model,
            arrival: r.arrival,
            deadline: r.deadline,
            state: ReqState::Queued,
        });
    }

    /// Rebuild the event heap without dead timer entries and release
    /// their payload slots. O(heap); amortized by the dead-fraction
    /// trigger in `run`.
    fn compact_events(&mut self) {
        let old = std::mem::take(&mut self.events);
        let mut live = Vec::with_capacity(old.len());
        for Reverse((t, seq, slot)) in old.into_vec() {
            let keep = match &self.ev_payload[slot] {
                Some(Ev::Timer { key, gen }) => self.timers.live(*key, *gen),
                Some(_) => true,
                None => {
                    debug_assert!(false, "queued event with empty payload");
                    false
                }
            };
            if keep {
                live.push(Reverse((t, seq, slot)));
            } else if self.ev_payload[slot].take().is_some() {
                self.ev_free.push(slot);
            }
        }
        self.events = BinaryHeap::from(live);
        self.dead_timers = 0;
    }

    fn handle_event(&mut self, ev: Ev) {
        match ev {
            Ev::Timer { key, gen } => {
                if !self.timers.matches(key, gen) {
                    // Canceled or superseded — its dead heap entry is
                    // gone now.
                    self.dead_timers = self.dead_timers.saturating_sub(1);
                    return;
                }
                self.timers.clear(key);
                let mut cmds = std::mem::take(&mut self.cmd_queue);
                cmds.clear();
                self.scheduler.on_timer(key, self.now, &mut cmds);
                self.apply_commands(cmds);
            }
            Ev::GpuDone { gpu, epoch } => {
                let finished = self.gpus[gpu.0 as usize].complete(epoch);
                let Some(batch) = finished else { return };
                let size = batch.requests.len() as u32;
                for &rid in batch.requests.iter() {
                    let rec = *self.req(rid);
                    let kind = if batch.end <= rec.deadline {
                        OutcomeKind::Good
                    } else {
                        OutcomeKind::Late
                    };
                    self.req_mut(rid).state = ReqState::Done;
                    self.metrics.record_outcome(
                        rec.model,
                        rec.arrival,
                        kind,
                        Some(batch.start),
                        Some(batch.end),
                        size,
                    );
                }
                if self.cfg.record_trace {
                    self.trace.push(TraceEntry {
                        gpu,
                        model: batch.model,
                        size,
                        start: batch.start,
                        end: batch.end,
                        preempted: false,
                    });
                }
                if self.gpus[gpu.0 as usize].retired {
                    return; // autoscaler already removed it
                }
                let mut cmds = std::mem::take(&mut self.cmd_queue);
                cmds.clear();
                self.scheduler.on_gpu_free(gpu, self.now, &mut cmds);
                self.apply_commands(cmds);
            }
            Ev::External { tag } => {
                let mut ops = ClusterOps {
                    gpus: &mut self.gpus,
                    metrics: &self.metrics,
                    added: Vec::new(),
                    removed: Vec::new(),
                };
                let next = self.driver.on_tick(tag, self.now, &mut ops);
                let (added, removed) = (ops.added, ops.removed);
                let mut cmds = std::mem::take(&mut self.cmd_queue);
                cmds.clear();
                for g in added {
                    self.scheduler.on_gpu_added(g, self.now, &mut cmds);
                }
                for g in removed {
                    self.scheduler.on_gpu_removed(g, self.now, &mut cmds);
                }
                self.apply_commands(cmds);
                if let Some(at) = next {
                    self.push_event(at, Ev::External { tag });
                }
            }
        }
    }

    fn apply_commands(&mut self, mut cmds: Vec<Command>) {
        let mut i = 0;
        while i < cmds.len() {
            // Take ownership without cloning (Dispatch carries the batch
            // id list — cloning it was the hottest allocation in the
            // §Perf profile).
            let cmd = std::mem::replace(&mut cmds[i], Command::Drop(ReqList::new()));
            i += 1;
            match cmd {
                Command::Dispatch {
                    gpu,
                    model,
                    requests,
                } => self.do_dispatch(gpu, model, requests),
                Command::Drop(ids) => {
                    for &rid in ids.iter() {
                        let rec = *self.req(rid);
                        debug_assert_eq!(
                            rec.state,
                            ReqState::Queued,
                            "dropping non-queued request"
                        );
                        self.req_mut(rid).state = ReqState::Dropped;
                        self.metrics.record_outcome(
                            rec.model,
                            rec.arrival,
                            OutcomeKind::Dropped,
                            None,
                            None,
                            0,
                        );
                    }
                }
                Command::SetTimer { key, at } => {
                    // Timers in the past fire "immediately" (clamped to
                    // now) — e.g. revalidation of an already-expired
                    // candidate window.
                    match self.timers.armed(key) {
                        // Re-arm at the unchanged deadline: the pending
                        // heap entry already fires there — skip the push
                        // (§Perf: timer churn; `update_candidate` re-arms
                        // on every arrival).
                        Some((_, armed_at)) if armed_at == at => {}
                        prev => {
                            if prev.is_some() {
                                self.dead_timers += 1;
                            }
                            self.timer_gen += 1;
                            self.timers.set(key, self.timer_gen, at);
                            self.push_event(at.max(self.now), Ev::Timer {
                                key,
                                gen: self.timer_gen,
                            });
                        }
                    }
                }
                Command::CancelTimer { key } => {
                    if self.timers.armed(key).is_some() {
                        self.dead_timers += 1;
                        self.timers.clear(key);
                    }
                }
                Command::Preempt { gpu } => {
                    let Some(batch) = self.gpus[gpu.0 as usize].preempt(self.now) else {
                        continue;
                    };
                    self.metrics.preempted_batches += 1;
                    self.metrics.wasted_work += batch.requests.len() as u64;
                    if self.cfg.record_trace {
                        self.trace.push(TraceEntry {
                            gpu,
                            model: batch.model,
                            size: batch.requests.len() as u32,
                            start: batch.start,
                            end: self.now,
                            preempted: true,
                        });
                    }
                    let reqs: Vec<Request> = batch
                        .requests
                        .iter()
                        .map(|rid| {
                            let rec = self.req_mut(*rid);
                            rec.state = ReqState::Queued;
                            Request {
                                id: *rid,
                                model: rec.model,
                                arrival: rec.arrival,
                                deadline: rec.deadline,
                            }
                        })
                        .collect();
                    let mut extra = Vec::new();
                    self.scheduler
                        .on_preempted(gpu, reqs, self.now, &mut extra);
                    cmds.extend(extra);
                }
            }
        }
        self.cmd_queue = cmds;
    }

    fn do_dispatch(&mut self, gpu: GpuId, model: ModelId, requests: ReqList) {
        assert!(!requests.is_empty(), "empty batch dispatched");
        let g = &mut self.gpus[gpu.0 as usize];
        assert!(!g.is_busy(), "dispatch to busy GPU {gpu:?} at {:?}", self.now);
        assert!(!g.retired, "dispatch to retired GPU {gpu:?}");
        let size = requests.len() as u32;
        let net = self.cfg.network.sample(&mut self.rng);
        let exec = self.model_spec(model).profile.latency(size);
        let start = self.now + net;
        let end = start + exec;
        for &rid in requests.iter() {
            let rec = self.req_mut(rid);
            debug_assert_eq!(rec.state, ReqState::Queued, "request not queued");
            rec.state = ReqState::Running;
        }
        let epoch = self.gpus[gpu.0 as usize].begin(model, requests, self.now, start, end);
        self.metrics.record_batch(size, start);
        self.push_event(end, Ev::GpuDone { gpu, epoch });
    }

    fn finalize(mut self) -> SimResult<S, D> {
        // Unfinished requests (queued or running at the horizon).
        for i in 0..self.requests.len() {
            let rec = self.requests[i];
            if matches!(rec.state, ReqState::Queued | ReqState::Running) {
                self.metrics.record_outcome(
                    rec.model,
                    rec.arrival,
                    OutcomeKind::Unfinished,
                    None,
                    None,
                    0,
                );
            }
        }
        // GPU busy time clipped to the metrics window.
        let w0 = self.metrics.cfg.warmup;
        for (i, g) in self.gpus.iter().enumerate() {
            // `busy` accumulated from t=0; subtract an estimate of the
            // pre-warmup fraction by scaling. For exactness experiments
            // use warmup=0; for goodput runs the steady-state approx is
            // fine. In-flight batch at the horizon still counts up to now.
            let mut busy = g.busy;
            if let Some(f) = &g.in_flight {
                if self.now > f.start {
                    busy += self.now.min(f.end) - f.start;
                }
            }
            let total = self.now;
            let busy_in_window = if w0 == Micros::ZERO || total <= w0 {
                busy
            } else {
                // Steady-state scaling of busy time into the window.
                let frac = (total - w0).as_secs_f64() / total.as_secs_f64();
                Micros::from_secs_f64(busy.as_secs_f64() * frac)
            };
            self.metrics.gpu_busy.insert(i as u32, busy_in_window);
        }
        self.metrics.window = (w0, self.now.max(w0));
        SimResult {
            metrics: self.metrics,
            scheduler: self.scheduler,
            driver: self.driver,
            trace: self.trace,
            events_processed: self.events_processed,
        }
    }

    pub fn events_processed(&self) -> u64 {
        self.events_processed
    }
}

/// Everything a finished run produces.
pub struct SimResult<S, D = NoDriver> {
    pub metrics: Metrics,
    pub scheduler: S,
    pub driver: D,
    pub trace: Vec<TraceEntry>,
    pub events_processed: u64,
}
