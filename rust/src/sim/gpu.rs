//! Emulated GPU state. The paper's end-to-end evaluation emulates GPUs
//! "by simply introducing a delay at the backend" from measured ℓ(b)
//! profiles (§5); this is the discrete-event equivalent, with busy-time
//! accounting for the utilization figures.

use crate::core::time::Micros;
use crate::core::types::{ModelId, ReqList};

/// The batch a GPU is currently executing.
#[derive(Clone, Debug)]
pub struct InFlight {
    pub model: ModelId,
    pub requests: ReqList,
    pub dispatched_at: Micros,
    pub start: Micros,
    pub end: Micros,
    /// Monotone token distinguishing this execution from a preempted one
    /// whose completion event is still in the queue.
    pub epoch: u64,
}

/// One emulated GPU.
#[derive(Clone, Debug, Default)]
pub struct GpuState {
    pub in_flight: Option<InFlight>,
    /// Accumulated busy time (within + outside the metrics window; the
    /// engine clips to the window when finalizing).
    pub busy: Micros,
    pub batches_run: u64,
    pub epoch: u64,
    /// Removed by the autoscaler — refuses new work.
    pub retired: bool,
}

impl GpuState {
    pub fn is_busy(&self) -> bool {
        self.in_flight.is_some()
    }

    /// Begin executing a batch; returns the epoch token for completion.
    pub fn begin(
        &mut self,
        model: ModelId,
        requests: ReqList,
        dispatched_at: Micros,
        start: Micros,
        end: Micros,
    ) -> u64 {
        debug_assert!(!self.is_busy(), "GPU double-booked");
        debug_assert!(!self.retired, "dispatch to retired GPU");
        self.epoch += 1;
        self.in_flight = Some(InFlight {
            model,
            requests,
            dispatched_at,
            start,
            end,
            epoch: self.epoch,
        });
        self.epoch
    }

    /// Normal completion at `end` — credit busy time, return the batch.
    pub fn complete(&mut self, epoch: u64) -> Option<InFlight> {
        match &self.in_flight {
            Some(f) if f.epoch == epoch => {
                let f = self.in_flight.take().unwrap();
                self.busy += f.end - f.start;
                self.batches_run += 1;
                Some(f)
            }
            _ => None, // stale completion of a preempted batch
        }
    }

    /// Preempt at `now` — busy time credited only for the executed part.
    pub fn preempt(&mut self, now: Micros) -> Option<InFlight> {
        let f = self.in_flight.take()?;
        if now > f.start {
            self.busy += now.min(f.end) - f.start;
        }
        Some(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::types::RequestId;

    #[test]
    fn lifecycle() {
        let mut g = GpuState::default();
        assert!(!g.is_busy());
        let ep = g.begin(
            ModelId(0),
            vec![RequestId(1)].into(),
            Micros(10),
            Micros(12),
            Micros(20),
        );
        assert!(g.is_busy());
        let f = g.complete(ep).unwrap();
        assert_eq!(f.requests, vec![RequestId(1)]);
        assert_eq!(g.busy, Micros(8));
        assert_eq!(g.batches_run, 1);
        assert!(!g.is_busy());
    }

    #[test]
    fn stale_completion_ignored_after_preempt() {
        let mut g = GpuState::default();
        let ep =
            g.begin(ModelId(0), vec![RequestId(1)].into(), Micros(0), Micros(0), Micros(100));
        let pre = g.preempt(Micros(40)).unwrap();
        assert_eq!(pre.requests, vec![RequestId(1)]);
        assert_eq!(g.busy, Micros(40));
        // The completion event for the preempted batch must be a no-op.
        assert!(g.complete(ep).is_none());
        assert_eq!(g.batches_run, 0);
    }

    #[test]
    #[should_panic(expected = "double-booked")]
    #[cfg(debug_assertions)]
    fn double_book_panics() {
        let mut g = GpuState::default();
        g.begin(ModelId(0), ReqList::new(), Micros(0), Micros(0), Micros(1));
        g.begin(ModelId(0), ReqList::new(), Micros(0), Micros(0), Micros(1));
    }
}
