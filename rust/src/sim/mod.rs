//! Discrete-event cluster emulator: event engine, emulated GPUs
//! (delay-injection from ℓ(b) profiles, the paper's own methodology),
//! and network latency models.

pub mod engine;
pub mod gpu;
pub mod network;

pub use engine::{ClusterOps, Engine, EngineDriver, NoDriver, SimConfig, SimResult, TraceEntry};
pub use network::NetworkModel;
