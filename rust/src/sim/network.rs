//! Network latency models (§4.3, §5.6, Appendix B).
//!
//! A dispatch decision travels scheduler → backend (control plane), then
//! the backend pulls inputs from frontends (data plane, one-sided RDMA
//! READ in the paper). The *sampled* delay is what the simulated batch
//! actually experiences; the *bound* is the high-percentile estimate the
//! scheduler budgets for ("The scheduler always uses the high percentile
//! bound of network latency as the network delay estimation", §5.6).
//!
//! `Rdma` and `Tcp` are calibrated to Appendix B / Figure 17: RDMA floor
//! 24 µs with a 99.99th percentile of 33 µs; TCP median 3034 µs with a
//! 99.99th percentile 12× the median.

use crate::core::time::Micros;
use crate::util::rng::Rng;

/// z-score of the 99.99th percentile of a normal distribution.
const Z9999: f64 = 3.719;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NetworkModel {
    /// No network (scheduler-only runs).
    Ideal,
    /// Deterministic latency — the Fig 14 sweep axis.
    Constant { latency: Micros },
    /// InfiniBand RDMA incast (Appendix B): 24 µs floor + light tail.
    Rdma,
    /// Kernel TCP incast (Appendix B): 3.0 ms median, 12× p99.99 tail.
    Tcp,
}

impl NetworkModel {
    /// Parameters of the lognormal tail component, `(floor_us, mu, sigma)`.
    fn lognormal_params(&self) -> Option<(f64, f64, f64)> {
        match self {
            NetworkModel::Ideal | NetworkModel::Constant { .. } => None,
            // Floor 24us; median tail ~3us (median total 27us), p9999
            // total 33us => sigma = ln(9/3)/z.
            NetworkModel::Rdma => Some((24.0, 3f64.ln(), (9f64 / 3.0).ln() / Z9999)),
            // Median 3034us, p9999 = 12x median.
            NetworkModel::Tcp => Some((0.0, 3034f64.ln(), 12f64.ln() / Z9999)),
        }
    }

    /// Sample one control+data round for a batch dispatch.
    pub fn sample(&self, rng: &mut Rng) -> Micros {
        match self {
            NetworkModel::Ideal => Micros::ZERO,
            NetworkModel::Constant { latency } => *latency,
            _ => {
                let (floor, mu, sigma) = self.lognormal_params().unwrap();
                Micros((floor + rng.lognormal(mu, sigma)).round() as u64)
            }
        }
    }

    /// High-percentile bound the scheduler budgets for (p99.99).
    pub fn bound(&self) -> Micros {
        match self {
            NetworkModel::Ideal => Micros::ZERO,
            NetworkModel::Constant { latency } => *latency,
            _ => {
                let (floor, mu, sigma) = self.lognormal_params().unwrap();
                Micros((floor + (mu + Z9999 * sigma).exp()).round() as u64)
            }
        }
    }

    pub fn name(&self) -> String {
        match self {
            NetworkModel::Ideal => "ideal".into(),
            NetworkModel::Constant { latency } => format!("const({latency})"),
            NetworkModel::Rdma => "rdma".into(),
            NetworkModel::Tcp => "tcp".into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::percentile;

    fn quantiles(model: NetworkModel, n: usize) -> (f64, f64, f64) {
        let mut rng = Rng::new(42);
        let mut xs: Vec<f64> = (0..n).map(|_| model.sample(&mut rng).0 as f64).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        (
            xs[0],
            percentile(&xs, 50.0),
            percentile(&xs, 99.99),
        )
    }

    #[test]
    fn rdma_matches_appendix_b() {
        let (min, med, p9999) = quantiles(NetworkModel::Rdma, 200_000);
        assert!(min >= 24.0, "floor {min}");
        assert!((26.0..30.0).contains(&med), "median {med}");
        // Paper: 99.99th within 33us.
        assert!((30.0..38.0).contains(&p9999), "p9999 {p9999}");
    }

    #[test]
    fn tcp_matches_appendix_b() {
        let (_, med, p9999) = quantiles(NetworkModel::Tcp, 400_000);
        assert!((2800.0..3300.0).contains(&med), "median {med}");
        // Paper: p99.99 = 12x median.
        let ratio = p9999 / med;
        assert!((9.0..16.0).contains(&ratio), "tail ratio {ratio}");
    }

    #[test]
    fn bound_is_conservative() {
        let mut rng = Rng::new(1);
        for model in [NetworkModel::Rdma, NetworkModel::Tcp] {
            let bound = model.bound();
            let over = (0..100_000)
                .filter(|_| model.sample(&mut rng) > bound)
                .count();
            // ~1e-4 exceed by construction.
            assert!(over < 60, "{}: {over} exceed bound {bound}", model.name());
        }
    }

    #[test]
    fn ideal_and_constant() {
        let mut rng = Rng::new(2);
        assert_eq!(NetworkModel::Ideal.sample(&mut rng), Micros::ZERO);
        let c = NetworkModel::Constant {
            latency: Micros(150),
        };
        assert_eq!(c.sample(&mut rng), Micros(150));
        assert_eq!(c.bound(), Micros(150));
    }
}
