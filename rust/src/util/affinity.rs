//! Core/NUMA-aware thread placement for the shard tiers (`--pin-cores`).
//!
//! Std-only (the offline registry has no `libc` crate, but the platform
//! already links libc): pinning is a direct `extern "C"`
//! `sched_setaffinity` declaration, and topology discovery parses the
//! kernel's own text interfaces —
//!
//! * `/sys/devices/system/node/node*/cpulist` — cores grouped by NUMA
//!   node, so a [`CorePlan`] hands out cores node-by-node and the three
//!   shard tiers of one coordinator land on the same socket before
//!   spilling to the next;
//! * `/sys/devices/system/cpu/online` — fallback when there is no NUMA
//!   sysfs (single-node hosts, some containers);
//! * `/proc/self/status` `Cpus_allowed_list` — the cgroup/taskset mask,
//!   intersected in so a containerized run never asks for a core it
//!   cannot have.
//!
//! On non-Linux everything degrades to a no-op: [`CorePlan::detect`]
//! comes back empty and [`pin_to`] returns false, so `--pin-cores` is
//! safe to pass anywhere.

/// Pin the **calling thread** to `cpu`. Returns whether the kernel
/// accepted the mask. No-op (false) on non-Linux.
#[cfg(target_os = "linux")]
pub fn pin_to(cpu: usize) -> bool {
    extern "C" {
        // pid 0 = the calling thread (Linux sched_setaffinity(2)).
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    const WORDS: usize = 16; // 1024 CPUs
    if cpu >= WORDS * 64 {
        return false;
    }
    let mut mask = [0u64; WORDS];
    mask[cpu / 64] |= 1u64 << (cpu % 64);
    // SAFETY: the declaration matches the Linux sched_setaffinity(2)
    // ABI; `mask` is a live stack array whose exact byte size is passed
    // as `cpusetsize`, and the kernel only reads the mask. pid 0 names
    // the calling thread, so no other process is touched.
    unsafe { sched_setaffinity(0, std::mem::size_of::<[u64; WORDS]>(), mask.as_ptr()) == 0 }
}

#[cfg(not(target_os = "linux"))]
pub fn pin_to(_cpu: usize) -> bool {
    false
}

/// Convenience for spawned threads: pin if the plan assigned a core.
pub fn pin(core: Option<usize>) -> bool {
    core.map(pin_to).unwrap_or(false)
}

/// Parse a kernel cpulist (`"0-3,5,8-9"`) into explicit core ids.
/// Malformed pieces are skipped, not fatal — these files are trusted
/// but the parser must never panic the serving path.
pub fn parse_cpu_list(s: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for part in s.trim().split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some((lo, hi)) = part.split_once('-') {
            if let (Ok(lo), Ok(hi)) = (lo.trim().parse::<usize>(), hi.trim().parse::<usize>()) {
                if lo <= hi && hi - lo < 4096 {
                    out.extend(lo..=hi);
                }
            }
        } else if let Ok(v) = part.parse::<usize>() {
            out.push(v);
        }
    }
    out
}

fn read_cpu_list(path: &str) -> Vec<usize> {
    std::fs::read_to_string(path)
        .map(|s| parse_cpu_list(&s))
        .unwrap_or_default()
}

/// The cgroup/taskset-allowed cores of this process, if discoverable.
fn allowed_cpus() -> Vec<usize> {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return Vec::new();
    };
    status
        .lines()
        .find_map(|l| l.strip_prefix("Cpus_allowed_list:"))
        .map(|list| parse_cpu_list(list))
        .unwrap_or_default()
}

/// Cores in NUMA-node order (`node0`'s cores, then `node1`'s, …), or
/// empty when the node sysfs is absent.
fn numa_ordered_cpus() -> Vec<usize> {
    let Ok(dir) = std::fs::read_dir("/sys/devices/system/node") else {
        return Vec::new();
    };
    let mut nodes: Vec<(usize, Vec<usize>)> = Vec::new();
    for entry in dir.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(idx) = name.strip_prefix("node").and_then(|s| s.parse::<usize>().ok()) else {
            continue;
        };
        let cpus = std::fs::read_to_string(entry.path().join("cpulist"))
            .map(|s| parse_cpu_list(&s))
            .unwrap_or_default();
        if !cpus.is_empty() {
            nodes.push((idx, cpus));
        }
    }
    nodes.sort_by_key(|&(idx, _)| idx);
    let mut seen = std::collections::HashSet::new();
    nodes
        .into_iter()
        .flat_map(|(_, cpus)| cpus)
        .filter(|c| seen.insert(*c))
        .collect()
}

/// A round-robin core assigner for thread placement. Built once at
/// spawn time; each tier's spawn loop calls [`CorePlan::assign`] and
/// the spawned thread pins itself via [`pin`].
#[derive(Debug, Default)]
pub struct CorePlan {
    cores: Vec<usize>,
    next: usize,
}

impl CorePlan {
    /// A plan that assigns nothing — `--pin-cores` off, tests, benches.
    pub fn disabled() -> Self {
        CorePlan::default()
    }

    /// Discover the host topology: NUMA-ordered cores (or the online
    /// list), intersected with the allowed mask. Empty on non-Linux or
    /// when discovery fails — callers then simply don't pin.
    pub fn detect() -> Self {
        let mut cores = numa_ordered_cpus();
        if cores.is_empty() {
            cores = read_cpu_list("/sys/devices/system/cpu/online");
        }
        if cores.is_empty() {
            let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0);
            cores = (0..n).collect();
        }
        let allowed = allowed_cpus();
        if !allowed.is_empty() {
            let allowed: std::collections::HashSet<usize> = allowed.into_iter().collect();
            cores.retain(|c| allowed.contains(c));
        }
        CorePlan { cores, next: 0 }
    }

    /// From an explicit core list (tests; future `--pin-cores 0-7`).
    pub fn from_cores(cores: Vec<usize>) -> Self {
        CorePlan { cores, next: 0 }
    }

    /// Next core, round-robin. `None` when the plan is disabled/empty.
    pub fn assign(&mut self) -> Option<usize> {
        if self.cores.is_empty() {
            return None;
        }
        let c = self.cores[self.next % self.cores.len()];
        self.next += 1;
        Some(c)
    }

    pub fn is_empty(&self) -> bool {
        self.cores.is_empty()
    }

    pub fn len(&self) -> usize {
        self.cores.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_ranges_singles_and_garbage() {
        assert_eq!(parse_cpu_list("0-3,5,8-9\n"), vec![0, 1, 2, 3, 5, 8, 9]);
        assert_eq!(parse_cpu_list("7"), vec![7]);
        assert_eq!(parse_cpu_list(""), Vec::<usize>::new());
        assert_eq!(parse_cpu_list("x,3-,,-2,4"), vec![4]);
        // Descending / absurd ranges are skipped, not panics.
        assert_eq!(parse_cpu_list("9-3"), Vec::<usize>::new());
    }

    #[test]
    fn plan_round_robins_and_disabled_assigns_nothing() {
        let mut p = CorePlan::from_cores(vec![2, 4, 6]);
        assert_eq!(p.len(), 3);
        let got: Vec<_> = (0..5).map(|_| p.assign().unwrap()).collect();
        assert_eq!(got, vec![2, 4, 6, 2, 4]);
        assert_eq!(CorePlan::disabled().assign(), None);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn detect_and_pin_on_linux() {
        // Detection must find at least the core we are running on, and
        // pinning to a detected core must be accepted by the kernel.
        let mut plan = CorePlan::detect();
        if let Some(core) = plan.assign() {
            assert!(pin_to(core), "sched_setaffinity rejected core {core}");
        }
    }
}
