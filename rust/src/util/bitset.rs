//! Allocation-free min-id GPU set. Replaces the `BTreeSet<GpuId>` free
//! set on the scheduler hot path: `insert`/`remove`/`contains` are O(1)
//! bit operations on preallocated words and never touch the allocator
//! (BTree nodes come and go with membership), and the min-id lookup —
//! Symphony's consolidation pick (§3.2) — scans 64 ids per step with
//! `trailing_zeros`.

use crate::core::types::GpuId;

/// A set of GPU ids backed by a bitmask word vector.
#[derive(Clone, Debug, Default)]
pub struct GpuSet {
    words: Vec<u64>,
    len: usize,
}

impl GpuSet {
    pub fn new() -> Self {
        GpuSet::default()
    }

    /// Preallocate room for ids `0..n_ids` so inserts in that range
    /// never grow the word vector.
    pub fn with_id_capacity(n_ids: usize) -> Self {
        GpuSet {
            words: vec![0; n_ids.div_ceil(64)],
            len: 0,
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Returns true if `g` was newly inserted.
    pub fn insert(&mut self, g: GpuId) -> bool {
        let w = (g.0 / 64) as usize;
        if w >= self.words.len() {
            self.words.resize(w + 1, 0);
        }
        let bit = 1u64 << (g.0 % 64);
        if self.words[w] & bit != 0 {
            return false;
        }
        self.words[w] |= bit;
        self.len += 1;
        true
    }

    /// Returns true if `g` was present.
    pub fn remove(&mut self, g: GpuId) -> bool {
        let w = (g.0 / 64) as usize;
        if w >= self.words.len() {
            return false;
        }
        let bit = 1u64 << (g.0 % 64);
        if self.words[w] & bit == 0 {
            return false;
        }
        self.words[w] &= !bit;
        self.len -= 1;
        true
    }

    #[inline]
    pub fn contains(&self, g: GpuId) -> bool {
        let w = (g.0 / 64) as usize;
        w < self.words.len() && self.words[w] & (1u64 << (g.0 % 64)) != 0
    }

    /// Smallest id in the set (the consolidation pick), if any.
    #[inline]
    pub fn min(&self) -> Option<GpuId> {
        for (i, &w) in self.words.iter().enumerate() {
            if w != 0 {
                return Some(GpuId((i as u32) * 64 + w.trailing_zeros()));
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_remove_contains_min() {
        let mut s = GpuSet::with_id_capacity(8);
        assert!(s.is_empty());
        assert_eq!(s.min(), None);
        assert!(s.insert(GpuId(5)));
        assert!(s.insert(GpuId(2)));
        assert!(!s.insert(GpuId(2)), "double insert");
        assert_eq!(s.len(), 2);
        assert!(s.contains(GpuId(2)) && s.contains(GpuId(5)));
        assert!(!s.contains(GpuId(3)));
        assert_eq!(s.min(), Some(GpuId(2)));
        assert!(s.remove(GpuId(2)));
        assert!(!s.remove(GpuId(2)), "double remove");
        assert_eq!(s.min(), Some(GpuId(5)));
        assert!(s.remove(GpuId(5)));
        assert!(s.is_empty());
    }

    #[test]
    fn spans_word_boundaries() {
        let mut s = GpuSet::new();
        for id in [0u32, 63, 64, 127, 128, 1000] {
            assert!(s.insert(GpuId(id)));
        }
        assert_eq!(s.len(), 6);
        assert_eq!(s.min(), Some(GpuId(0)));
        assert!(s.remove(GpuId(0)));
        assert_eq!(s.min(), Some(GpuId(63)));
        assert!(s.remove(GpuId(63)));
        assert_eq!(s.min(), Some(GpuId(64)));
        assert!(s.contains(GpuId(1000)));
        assert!(!s.contains(GpuId(2000)), "beyond allocated words");
    }

    #[test]
    fn matches_btreeset_under_random_ops() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(42);
        let mut s = GpuSet::with_id_capacity(100);
        let mut reference = std::collections::BTreeSet::new();
        for _ in 0..10_000 {
            let id = GpuId(rng.below(100) as u32);
            if rng.f64() < 0.5 {
                assert_eq!(s.insert(id), reference.insert(id));
            } else {
                assert_eq!(s.remove(id), reference.remove(&id));
            }
            assert_eq!(s.len(), reference.len());
            assert_eq!(s.min(), reference.iter().next().copied());
        }
    }
}
