//! Minimal error type standing in for `anyhow` (unavailable in the
//! offline registry, like `proptest` — see [`crate::util::proptest`]).
//! Provides the same surface the runtime/serving layers use:
//! [`Result`], [`Context::context`]/[`Context::with_context`], and the
//! [`bail!`](crate::bail) macro.

use std::fmt;

/// A string-backed error with optional context prefixes.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl fmt::Display) -> Self {
        Error {
            msg: msg.to_string(),
        }
    }

    fn wrap(self, ctx: impl fmt::Display) -> Self {
        Error {
            msg: format!("{ctx}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// Drop-in for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a fallible value (`anyhow::Context` equivalent).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow::bail!` equivalent: early-return a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($fmt:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($fmt)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("bad value {}", 7)
    }

    #[test]
    fn bail_and_context_compose() {
        let e = fails().context("loading").unwrap_err();
        assert_eq!(e.to_string(), "loading: bad value 7");
        let e = fails()
            .with_context(|| format!("pass {}", 2))
            .unwrap_err();
        assert_eq!(e.to_string(), "pass 2: bad value 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
        assert_eq!(Some(3).context("x").unwrap(), 3);
    }

    #[test]
    fn io_error_converts() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here")?;
            Ok(s)
        }
        assert!(read().is_err());
    }
}
