//! Shared infrastructure: deterministic RNG + distributions, statistics,
//! table/TSV output, and the mini property-test runner.

pub mod affinity;
pub mod bitset;
pub mod error;
pub mod par;
pub mod proptest;
pub mod ring;
pub mod rng;
pub mod shim;
pub mod stats;
pub mod sync;
pub mod table;
