//! Tiny data-parallel map over std threads (no rayon offline). Used by
//! the experiment sweeps; each item must be independent.

/// Map `f` over `items` using up to `available_parallelism` threads,
/// preserving order.
pub fn par_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(items.len().max(1));
    if n_threads <= 1 || items.len() <= 1 {
        return items.iter().map(|t| f(t)).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    let out_slots: Vec<std::sync::Mutex<&mut Option<R>>> =
        out.iter_mut().map(std::sync::Mutex::new).collect();
    std::thread::scope(|s| {
        for _ in 0..n_threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                **out_slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    drop(out_slots);
    out.into_iter().map(|r| r.expect("slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..100).collect();
        let out = par_map(items, |&x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn handles_empty_and_single() {
        assert!(par_map(Vec::<u32>::new(), |&x| x).is_empty());
        assert_eq!(par_map(vec![7], |&x| x + 1), vec![8]);
    }
}
