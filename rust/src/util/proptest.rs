//! Minimal property-testing helper (the `proptest` crate is unavailable
//! in the offline registry). Runs a property over many seeded random
//! cases and reports the failing seed so a failure is reproducible with
//! `SYMPHONY_PROP_SEED=<seed>`.

use crate::util::rng::Rng;

/// Number of cases per property (override with SYMPHONY_PROP_CASES).
pub fn default_cases() -> u64 {
    std::env::var("SYMPHONY_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `cases` seeded RNGs; panic with the seed on failure.
///
/// `prop` returns `Err(message)` to fail the case.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    if let Ok(seed) = std::env::var("SYMPHONY_PROP_SEED") {
        let seed: u64 = seed.parse().expect("SYMPHONY_PROP_SEED must be u64");
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name} failed (seed {seed}): {msg}");
        }
        return;
    }
    let base: u64 = 0xC0FF_EE00;
    for case in 0..cases {
        let seed = base.wrapping_add(case);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name} failed on case {case} (reproduce with \
                 SYMPHONY_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Convenience assertion macro for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("trivial", 16, |rng| {
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "SYMPHONY_PROP_SEED")]
    fn reports_seed_on_failure() {
        check("always_fails", 4, |_| Err("nope".into()));
    }
}
