//! Bounded lock-free MPSC ring — the coordinator's inter-thread fabric.
//!
//! Every steady-state hop of the submit→grant path (producer → ingest
//! shard, ingest shard → model worker, model worker ⇄ rank shard) used
//! to ride `std::sync::mpsc`: one heap-allocated node per send and a
//! futex wake per `recv_timeout`. This module replaces those hops with
//! a hand-rolled bounded ring (the registry is offline, so no
//! crossbeam — same discipline as `util/error.rs` and `net/codec.rs`):
//!
//! * **Vyukov-style slots**: each slot carries its own sequence atomic,
//!   so producers claim positions with one CAS on the tail and publish
//!   with one release store — no producer ever touches another
//!   producer's slot, and the (single) consumer never contends with
//!   producers except on the slot being handed over.
//! * **Cache-padded cursors**: head and tail live on their own cache
//!   lines so producer claims don't false-share with consumer advances.
//! * **Power-of-two capacity**: slot lookup is a mask, not a modulo.
//! * **Adaptive blocking edge**: receivers spin → yield ([`Waiter`])
//!   and only then park on a Condvar [`Parker`]; an opt-in busy-poll
//!   mode (`--busy-poll`) keeps drain threads spinning for
//!   latency-critical deployments.
//!
//! **Full-queue policy is the call site's contract**, documented there:
//! request-rate traffic (frontend submissions, ingest→worker bursts)
//! uses [`RingSender::try_send`] and counts a full ring into
//! `dropped_submits` — under overload the ring is the shed point, same
//! as the seed's behavior of losing to an unbounded queue's latency.
//! Control traffic (grants, revalidations, drains — messages that must
//! not drop) uses [`RingSender::send`], a bounded spin-then-sleep retry:
//! it gives up only after [`SEND_RETRY_BOUND`], turning a wedged peer
//! into a counted error instead of a deadlock (bounded rings can form
//! a cycle worker ⇄ shard that unbounded mpsc could not).
//!
//! Error types mirror `std::sync::mpsc`'s names so call sites port
//! with an import swap.
//!
//! **Checked by `symphony check`**: every atomic, fence, blocking edge,
//! and slot-payload access below goes through the [`Fabric`] shim
//! (`util/shim.rs`). The public types are aliases instantiating the
//! generic protocol code at [`RealFabric`] (zero-cost); the model
//! checker instantiates the *same* code at `check::virt::VirtFabric`
//! and enumerates its interleavings. Keep new synchronization on the
//! shim, or the checker goes blind to it.

use std::cell::{Cell, UnsafeCell};
use std::fmt;
use std::marker::PhantomData;
use std::mem::MaybeUninit;
use std::sync::atomic::Ordering;
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

use super::shim::{Fabric, RealFabric, ShimAtomic, ShimBlocker};

/// How long a blocking [`RingSender::send`] retries against a full ring
/// before reporting failure. Control messages must not drop; this bound
/// only exists so a wedged (or deadlocked-by-cycle) peer surfaces as an
/// error instead of hanging shutdown forever.
pub const SEND_RETRY_BOUND: Duration = Duration::from_secs(5);

// ---------------------------------------------------------------- errors

#[derive(Debug, PartialEq, Eq)]
pub enum TrySendError<T> {
    /// The ring is full. Shed or retry per the call site's policy.
    Full(T),
    /// The receiver is gone; the value comes back to the caller.
    Disconnected(T),
}

/// The receiver is gone (or [`SEND_RETRY_BOUND`] elapsed on a full
/// ring); the value comes back to the caller.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TryRecvError {
    Empty,
    Disconnected,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecvError;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecvTimeoutError {
    Timeout,
    Disconnected,
}

// ---------------------------------------------------------------- waiter

/// Process-wide cache of the `SYMPHONY_BUSY_POLL` environment lookup.
/// [`Waiter::from_env`] used to issue a `var_os` syscall per
/// construction, and drain-restart paths construct a fresh `Waiter`
/// every wakeup; the environment is fixed at process start for every
/// deployment mode we ship, so one lookup serves the process lifetime.
static BUSY_POLL_ENV: OnceLock<bool> = OnceLock::new();

/// Whether `SYMPHONY_BUSY_POLL` was set when first consulted (cached).
pub fn busy_poll_env() -> bool {
    *BUSY_POLL_ENV.get_or_init(|| std::env::var_os("SYMPHONY_BUSY_POLL").is_some())
}

/// The shared idle policy for drain loops: spin (with escalating
/// `spin_loop` hints) → `yield_now` → block. The ring's receivers use
/// it internally before parking; the wire writer uses it before its
/// Condvar wait. Under busy-poll, [`Waiter::should_block`] never turns
/// true, so the loop spins/yields forever — the opt-in latency mode.
///
/// The spin/yield budget comes from the fabric ([`Fabric::spin_budget`]):
/// 64+32 rounds for [`RealFabric`], zero under the model checker (a
/// spin ladder is pure state-space when schedules are enumerated, and
/// the park edge is the protocol under test).
pub struct GenericWaiter<F: Fabric = RealFabric> {
    rounds: u32,
    spin_rounds: u32,
    yield_rounds: u32,
    busy_poll: bool,
    _fabric: PhantomData<fn() -> F>,
}

/// [`GenericWaiter`] on the production fabric.
pub type Waiter = GenericWaiter<RealFabric>;

impl<F: Fabric> fmt::Debug for GenericWaiter<F> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Waiter")
            .field("rounds", &self.rounds)
            .field("busy_poll", &self.busy_poll)
            .finish()
    }
}

impl<F: Fabric> GenericWaiter<F> {
    pub fn new(busy_poll: bool) -> Self {
        let (spin_rounds, yield_rounds) = F::spin_budget();
        GenericWaiter {
            rounds: 0,
            spin_rounds,
            yield_rounds,
            busy_poll,
            _fabric: PhantomData,
        }
    }

    /// Like [`Waiter::new`], but the `SYMPHONY_BUSY_POLL` environment
    /// variable also turns busy-poll on — the hook the bench smoke
    /// steps use to exercise the spin mode without new bench flags.
    /// The lookup is cached process-wide ([`busy_poll_env`]).
    pub fn from_env(busy_poll: bool) -> Self {
        Self::new(busy_poll || busy_poll_env())
    }

    /// Call after making progress so the ladder restarts at spinning.
    pub fn reset(&mut self) {
        self.rounds = 0;
    }

    /// Spin+yield budget exhausted — time to truly block (park /
    /// Condvar-wait). Never under busy-poll.
    pub fn should_block(&self) -> bool {
        !self.busy_poll && self.rounds >= self.spin_rounds + self.yield_rounds
    }

    /// One step of the spin→yield ladder.
    pub fn idle(&mut self) {
        if self.rounds < self.spin_rounds {
            for _ in 0..(1u32 << (self.rounds / 8).min(6)) {
                std::hint::spin_loop();
            }
        } else {
            std::thread::yield_now();
        }
        self.rounds = self.rounds.saturating_add(1);
    }

    pub fn busy_poll(&self) -> bool {
        self.busy_poll
    }
}

// ---------------------------------------------------------------- parker

const EMPTY: usize = 0;
const PARKED: usize = 1;
const NOTIFIED: usize = 2;

/// Condvar-based blocking edge for the ring's consumer. The state
/// machine (EMPTY → PARKED → NOTIFIED/EMPTY) keeps the producer side
/// cheap: a send only touches the Mutex when the consumer has actually
/// announced it is parked.
///
/// Wake-not-lost protocol (Dekker): the consumer stores `PARKED`
/// (SeqCst) and only then re-checks the ring; the producer publishes
/// its slot and only then (behind a SeqCst fence in [`Parker::wake`])
/// loads the state. Whatever the interleaving, at least one side sees
/// the other: either the consumer's re-check finds the message, or the
/// producer finds `PARKED` and notifies under the Mutex.
///
/// `symphony check` explores this protocol exhaustively (models
/// `parker-wake` / `parker-cancel`), including under TSO store
/// buffering — remove either SeqCst edge and the `seeded-parker-nofence`
/// variant shows the lost wake as a detected deadlock.
pub struct GenericParker<F: Fabric = RealFabric> {
    state: F::Atomic,
    blocker: F::Blocker,
}

/// [`GenericParker`] on the production fabric.
pub type Parker = GenericParker<RealFabric>;

impl<F: Fabric> Default for GenericParker<F> {
    fn default() -> Self {
        Self::new()
    }
}

impl<F: Fabric> GenericParker<F> {
    pub fn new() -> Self {
        GenericParker {
            state: F::atomic(EMPTY),
            blocker: F::blocker(),
        }
    }

    /// Announce intent to park. The caller MUST re-check its wake
    /// condition after this call and either [`Parker::cancel`] or
    /// [`Parker::park`].
    pub fn prepare(&self) {
        self.state.store(PARKED, Ordering::SeqCst);
        F::fence_seqcst();
    }

    /// Withdraw a [`Parker::prepare`] (the re-check found work).
    pub fn cancel(&self) {
        self.state.store(EMPTY, Ordering::SeqCst);
    }

    /// Block until notified or `deadline` (`None` = forever). Returns
    /// true if a wake was observed.
    pub fn park(&self, deadline: Option<Instant>) -> bool {
        self.blocker
            .block_while(&mut || self.state.load(Ordering::SeqCst) == PARKED, deadline);
        self.state.swap(EMPTY, Ordering::SeqCst) == NOTIFIED
    }

    /// Wake a parked consumer. Cheap when nobody is parked (one fenced
    /// load); takes the Mutex only to close the race with a concurrent
    /// `wait` entry — the CAS runs under the same lock the waiter
    /// re-checks under ([`ShimBlocker::update_and_notify`]).
    pub fn wake(&self) {
        F::fence_seqcst();
        if self.state.load(Ordering::SeqCst) == PARKED {
            self.blocker.update_and_notify(&mut || {
                self.state
                    .compare_exchange(PARKED, NOTIFIED, Ordering::SeqCst, Ordering::SeqCst)
                    .is_ok()
            });
        }
    }
}

// ------------------------------------------------------------------ ring

/// Head/tail cursors on their own cache lines.
#[repr(align(64))]
struct Padded<A>(A);

struct Slot<T, F: Fabric> {
    /// Vyukov sequence: `== pos` → empty, claimable by the producer
    /// that wins the tail CAS at `pos`; `== pos + 1` → published,
    /// readable by the consumer; `== pos + capacity` → consumed,
    /// claimable again on the next lap.
    seq: F::Atomic,
    val: UnsafeCell<MaybeUninit<T>>,
    /// Race-detector identity for `val` under the checker; `()` in
    /// real builds.
    tok: F::CellToken,
}

struct Inner<T, F: Fabric> {
    buf: Box<[Slot<T, F>]>,
    mask: usize,
    /// Producer claim cursor (CAS).
    tail: Padded<F::Atomic>,
    /// Consumer cursor — only the receiver advances it.
    head: Padded<F::Atomic>,
    senders: F::Atomic,
    /// 1 while the receiver handle is alive.
    rx_alive: F::Atomic,
    /// High-watermark occupancy gauge — max observed depth at publish
    /// time, monotone for the ring's lifetime. Advisory only (never
    /// read by the protocol), surfaced on `/metrics`.
    hwm: F::Atomic,
    parker: GenericParker<F>,
}

// SAFETY: the UnsafeCell slots are handed between threads under the
// Vyukov sequence protocol — a slot's value is only written by the
// producer that won the CAS for that position and only read by the
// single consumer after observing the producer's release store, so
// `T: Send` suffices.
unsafe impl<T: Send, F: Fabric> Send for Inner<T, F> {}
// SAFETY: same protocol as the Send impl above — shared references to
// `Inner` only ever touch a slot payload on the unique side of a
// sequence handoff, so no `T: Sync` is needed.
unsafe impl<T: Send, F: Fabric> Sync for Inner<T, F> {}

impl<T, F: Fabric> Drop for Inner<T, F> {
    fn drop(&mut self) {
        // Runs only once every handle is gone: drain whatever was
        // published but never consumed.
        // relaxed: this drop has `&mut self` — every handle is gone,
        // so no other thread can race these cursor/sequence loads.
        let mut pos = self.head.0.load(Ordering::Relaxed);
        loop {
            let slot = &self.buf[pos & self.mask];
            // relaxed: same single-threaded drop as the head load above.
            if slot.seq.load(Ordering::Relaxed) == pos.wrapping_add(1) {
                F::cell_read(&slot.tok);
                // SAFETY: seq == pos + 1 means this slot was published
                // and never consumed; with all handles gone we hold the
                // only reference, so reading and dropping it once is
                // sound.
                unsafe { (*slot.val.get()).assume_init_drop() };
                pos = pos.wrapping_add(1);
            } else {
                break;
            }
        }
    }
}

impl<T, F: Fabric> Inner<T, F> {
    fn enqueue(&self, v: T) -> Result<(), T> {
        // relaxed: a stale tail is re-validated by the CAS below; the
        // slot handoff itself orders via the seq Acquire/Release pair.
        let mut pos = self.tail.0.load(Ordering::Relaxed);
        loop {
            let slot = &self.buf[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let dif = seq.wrapping_sub(pos) as isize;
            if dif == 0 {
                // relaxed: the CAS only claims a position; publication
                // ordering rides the slot's seq Release store below,
                // never the tail cursor.
                match self.tail.0.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        F::cell_write(&slot.tok);
                        // SAFETY: winning the tail CAS at `pos` grants
                        // this producer exclusive write access to the
                        // slot (seq == pos ruled out concurrent
                        // owners); the consumer reads it only after
                        // the Release store of pos + 1 below.
                        unsafe { (*slot.val.get()).write(v) };
                        slot.seq.store(pos.wrapping_add(1), Ordering::Release);
                        self.note_depth(pos.wrapping_add(1));
                        return Ok(());
                    }
                    Err(now) => pos = now,
                }
            } else if dif < 0 {
                return Err(v); // full lap: consumer hasn't freed this slot
            } else {
                // relaxed: lost the claim race; reload and re-validate
                // through the same Acquire seq load next iteration.
                pos = self.tail.0.load(Ordering::Relaxed);
            }
        }
    }

    /// Single-consumer dequeue (no CAS on head — only the receiver
    /// calls this).
    fn dequeue(&self) -> Option<T> {
        // relaxed: only the single consumer writes head, and this *is*
        // the consumer — it always sees its own last store.
        let pos = self.head.0.load(Ordering::Relaxed);
        let slot = &self.buf[pos & self.mask];
        if slot.seq.load(Ordering::Acquire) == pos.wrapping_add(1) {
            F::cell_read(&slot.tok);
            // SAFETY: the Acquire load saw seq == pos + 1, so the
            // producer's Release publication happens-before this read;
            // the single consumer takes the value exactly once and
            // recycles the slot with the Release store below.
            let v = unsafe { (*slot.val.get()).assume_init_read() };
            slot.seq
                .store(pos.wrapping_add(self.buf.len()), Ordering::Release);
            self.head.0.store(pos.wrapping_add(1), Ordering::Release);
            Some(v)
        } else {
            None
        }
    }

    /// Consumer-side peek: is a message published at head?
    fn has_next(&self) -> bool {
        // relaxed: consumer-owned cursor, same as dequeue.
        let pos = self.head.0.load(Ordering::Relaxed);
        self.buf[pos & self.mask].seq.load(Ordering::Acquire) == pos.wrapping_add(1)
    }

    fn rx_alive(&self) -> bool {
        self.rx_alive.load(Ordering::Acquire) == 1
    }

    /// Producer-side gauge update after publishing at `tail_after - 1`.
    /// The shim's [`ShimAtomic`] has no `fetch_max`, so the max rides a
    /// `fetch_update` that short-circuits (returns `None`, no CAS) when
    /// the observed depth is not a new high.
    fn note_depth(&self, tail_after: usize) {
        if !F::track_gauges() {
            return;
        }
        // relaxed: advisory gauge — a stale head under-reports depth by
        // a few slots and the fetch_update CAS keeps the max monotone;
        // nothing in the handoff protocol reads this value.
        let head = self.head.0.load(Ordering::Relaxed);
        let depth = tail_after.wrapping_sub(head);
        let _ = self
            .hwm
            // relaxed: same advisory gauge as above.
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
                (depth > cur).then_some(depth)
            });
    }

    /// Instantaneous occupancy: published-or-claimed minus consumed.
    /// Advisory — both cursors can move between the two loads.
    fn depth(&self) -> usize {
        // relaxed: advisory gauge, see note_depth.
        let tail = self.tail.0.load(Ordering::Relaxed);
        // relaxed: advisory gauge, see note_depth.
        let head = self.head.0.load(Ordering::Relaxed);
        tail.wrapping_sub(head).min(self.buf.len())
    }
}

// ----------------------------------------------------------------- probe

/// Type-erased occupancy probe over a live ring. Metrics code holds
/// `Arc<dyn RingProbe>`s for rings of heterogeneous payload types and
/// polls them at scrape time; a probe keeps the ring's storage alive
/// but cannot send, receive, or block.
pub trait RingProbe: Send + Sync {
    /// Instantaneous occupancy (claimed-or-published minus consumed).
    fn depth(&self) -> usize;
    /// Max depth ever observed at publish time (monotone).
    fn high_watermark(&self) -> usize;
    /// Ring capacity after power-of-two rounding.
    fn capacity(&self) -> usize;
}

impl<T: Send, F: Fabric> RingProbe for Inner<T, F> {
    fn depth(&self) -> usize {
        Inner::depth(self)
    }

    fn high_watermark(&self) -> usize {
        // relaxed: advisory gauge, see note_depth.
        self.hwm.load(Ordering::Relaxed)
    }

    fn capacity(&self) -> usize {
        self.buf.len()
    }
}

/// Create a bounded MPSC ring on the production fabric. `capacity` is
/// rounded up to the next power of two (min 2).
pub fn ring<T>(capacity: usize) -> (RingSender<T>, RingReceiver<T>) {
    ring_in::<T, RealFabric>(capacity)
}

/// [`ring`], generic over the [`Fabric`] — how `symphony check` builds
/// the same ring on its instrumented virtual fabric.
pub fn ring_in<T, F: Fabric>(capacity: usize) -> (RingSender<T, F>, RingReceiver<T, F>) {
    let cap = capacity.max(2).next_power_of_two();
    let buf: Box<[Slot<T, F>]> = (0..cap)
        .map(|i| Slot {
            seq: F::atomic(i),
            val: UnsafeCell::new(MaybeUninit::uninit()),
            tok: F::cell_token(),
        })
        .collect();
    let inner = Arc::new(Inner {
        buf,
        mask: cap - 1,
        tail: Padded(F::atomic(0)),
        head: Padded(F::atomic(0)),
        senders: F::atomic(1),
        rx_alive: F::atomic(1),
        hwm: F::atomic(0),
        parker: GenericParker::new(),
    });
    (
        RingSender {
            inner: inner.clone(),
        },
        RingReceiver {
            inner,
            busy_poll: Cell::new(false),
        },
    )
}

// ---------------------------------------------------------------- sender

pub struct RingSender<T, F: Fabric = RealFabric> {
    inner: Arc<Inner<T, F>>,
}

impl<T, F: Fabric> Clone for RingSender<T, F> {
    fn clone(&self) -> Self {
        // relaxed: the counter only needs atomicity — a new handle is
        // handed to another thread through some already-synchronizing
        // channel (spawn, send), which orders the increment; the drop
        // side's AcqRel decrement pairs the final-count edge.
        self.inner.senders.fetch_add(1, Ordering::Relaxed);
        RingSender {
            inner: self.inner.clone(),
        }
    }
}

impl<T, F: Fabric> Drop for RingSender<T, F> {
    fn drop(&mut self) {
        if self.inner.senders.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Last producer gone: a blocked receiver must observe the
            // disconnect rather than sleep forever.
            self.inner.parker.wake();
        }
    }
}

impl<T, F: Fabric> RingSender<T, F> {
    /// Non-blocking send. `Full` is the caller's shed point (the
    /// documented ingest policy: count into `dropped_submits`).
    pub fn try_send(&self, v: T) -> Result<(), TrySendError<T>> {
        if !self.inner.rx_alive() {
            return Err(TrySendError::Disconnected(v));
        }
        match self.inner.enqueue(v) {
            Ok(()) => {
                self.inner.parker.wake();
                Ok(())
            }
            Err(v) => {
                if self.inner.rx_alive() {
                    Err(TrySendError::Full(v))
                } else {
                    Err(TrySendError::Disconnected(v))
                }
            }
        }
    }

    /// Blocking send with bounded retry — the control-traffic policy
    /// (grants, revalidations, drains, shutdowns must not drop). Spins,
    /// yields, then sleeps in 100 µs steps; gives up only after
    /// [`SEND_RETRY_BOUND`] so a wedged peer surfaces as an error
    /// instead of a deadlock.
    pub fn send(&self, v: T) -> Result<(), SendError<T>> {
        let mut v = v;
        let mut waiter = GenericWaiter::<F>::new(false);
        let mut deadline: Option<Instant> = None;
        loop {
            match self.try_send(v) {
                Ok(()) => return Ok(()),
                Err(TrySendError::Disconnected(x)) => return Err(SendError(x)),
                Err(TrySendError::Full(x)) => {
                    v = x;
                    let now = Instant::now();
                    let d = *deadline.get_or_insert(now + SEND_RETRY_BOUND);
                    if now >= d {
                        return Err(SendError(v));
                    }
                    if waiter.should_block() {
                        std::thread::sleep(Duration::from_micros(100));
                    } else {
                        waiter.idle();
                    }
                }
            }
        }
    }

    /// Type-erased occupancy probe; see [`RingProbe`].
    pub fn probe(&self) -> Arc<dyn RingProbe>
    where
        T: Send + 'static,
    {
        self.inner.clone()
    }
}

// -------------------------------------------------------------- receiver

/// The single consumer. `Send` but not `Sync` (the `Cell` sees to
/// that): exactly one thread may drain.
pub struct RingReceiver<T, F: Fabric = RealFabric> {
    inner: Arc<Inner<T, F>>,
    busy_poll: Cell<bool>,
}

impl<T, F: Fabric> Drop for RingReceiver<T, F> {
    fn drop(&mut self) {
        self.inner.rx_alive.store(0, Ordering::Release);
        // Unconsumed values are dropped by Inner::drop once the last
        // sender handle goes too.
    }
}

impl<T, F: Fabric> RingReceiver<T, F> {
    /// Opt this receiver's blocking waits into busy-poll: spin/yield
    /// until the deadline instead of parking (`--busy-poll`).
    pub fn set_busy_poll(&self, on: bool) {
        self.busy_poll.set(on);
    }

    pub fn try_recv(&self) -> Result<T, TryRecvError> {
        if let Some(v) = self.inner.dequeue() {
            return Ok(v);
        }
        if self.inner.senders.load(Ordering::Acquire) == 0 {
            // The last send and the handle-count decrement race; the
            // count reaching zero happens-after every publish, so one
            // more dequeue settles it.
            match self.inner.dequeue() {
                Some(v) => Ok(v),
                None => Err(TryRecvError::Disconnected),
            }
        } else {
            Err(TryRecvError::Empty)
        }
    }

    fn recv_deadline(&self, deadline: Option<Instant>) -> Result<T, RecvTimeoutError> {
        let mut waiter = GenericWaiter::<F>::new(self.busy_poll.get());
        loop {
            match self.try_recv() {
                Ok(v) => return Ok(v),
                Err(TryRecvError::Disconnected) => return Err(RecvTimeoutError::Disconnected),
                Err(TryRecvError::Empty) => {}
            }
            if let Some(d) = deadline {
                if Instant::now() >= d {
                    return Err(RecvTimeoutError::Timeout);
                }
            }
            if waiter.should_block() {
                self.inner.parker.prepare();
                // Dekker re-check: a producer may have published (and
                // skipped the wake) between try_recv and the PARKED
                // store above.
                if self.inner.has_next() || self.inner.senders.load(Ordering::SeqCst) == 0 {
                    self.inner.parker.cancel();
                    continue;
                }
                self.inner.parker.park(deadline);
                waiter.reset();
            } else {
                waiter.idle();
            }
        }
    }

    /// Blocking receive (spin → yield → park).
    pub fn recv(&self) -> Result<T, RecvError> {
        self.recv_deadline(None).map_err(|_| RecvError)
    }

    /// Blocking receive with a timeout; under busy-poll the wait
    /// spins/yields to the deadline instead of parking.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<T, RecvTimeoutError> {
        match self.try_recv() {
            Ok(v) => return Ok(v),
            Err(TryRecvError::Disconnected) => return Err(RecvTimeoutError::Disconnected),
            Err(TryRecvError::Empty) => {}
        }
        self.recv_deadline(Some(Instant::now() + timeout))
    }

    /// Pop up to `max` messages into `out`; returns how many.
    pub fn drain_into(&self, out: &mut Vec<T>, max: usize) -> usize {
        let mut n = 0;
        while n < max {
            match self.inner.dequeue() {
                Some(v) => {
                    out.push(v);
                    n += 1;
                }
                None => break,
            }
        }
        n
    }

    /// Iterator over currently-available messages (stops at Empty or
    /// Disconnected, like `std::sync::mpsc::Receiver::try_iter`).
    pub fn try_iter(&self) -> TryIter<'_, T, F> {
        TryIter { rx: self }
    }

    /// Ring capacity after power-of-two rounding.
    pub fn capacity(&self) -> usize {
        self.inner.buf.len()
    }

    /// Instantaneous occupancy (advisory, see [`RingProbe::depth`]).
    pub fn depth(&self) -> usize {
        self.inner.depth()
    }

    /// Max depth ever observed at publish time.
    pub fn high_watermark(&self) -> usize {
        // relaxed: advisory gauge, see Inner::note_depth.
        self.inner.hwm.load(Ordering::Relaxed)
    }

    /// Type-erased occupancy probe; see [`RingProbe`].
    pub fn probe(&self) -> Arc<dyn RingProbe>
    where
        T: Send + 'static,
    {
        self.inner.clone()
    }
}

pub struct TryIter<'a, T, F: Fabric = RealFabric> {
    rx: &'a RingReceiver<T, F>,
}

impl<T, F: Fabric> Iterator for TryIter<'_, T, F> {
    type Item = T;
    fn next(&mut self) -> Option<T> {
        self.rx.try_recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn fifo_and_capacity_rounding() {
        let (tx, rx) = ring::<u32>(3); // rounds to 4
        assert_eq!(rx.capacity(), 4);
        for i in 0..4 {
            tx.try_send(i).unwrap();
        }
        assert!(matches!(tx.try_send(4), Err(TrySendError::Full(4))));
        for i in 0..4 {
            assert_eq!(rx.try_recv(), Ok(i));
        }
        assert_eq!(rx.try_recv(), Err(TryRecvError::Empty));
    }

    #[test]
    fn disconnects_both_ways() {
        let (tx, rx) = ring::<u8>(4);
        tx.try_send(7).unwrap();
        drop(tx);
        assert_eq!(rx.try_recv(), Ok(7)); // buffered survives sender drop
        assert_eq!(rx.try_recv(), Err(TryRecvError::Disconnected));

        let (tx, rx) = ring::<u8>(4);
        drop(rx);
        assert!(matches!(tx.try_send(1), Err(TrySendError::Disconnected(1))));
        assert!(matches!(tx.send(2), Err(SendError(2))));
    }

    #[test]
    fn recv_timeout_times_out_empty() {
        let (_tx, rx) = ring::<u8>(4);
        let t0 = Instant::now();
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(20)),
            Err(RecvTimeoutError::Timeout)
        );
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn blocking_recv_wakes_on_send() {
        let (tx, rx) = ring::<u64>(8);
        let h = std::thread::spawn(move || rx.recv().unwrap());
        std::thread::sleep(Duration::from_millis(30)); // let it park
        tx.try_send(99).unwrap();
        assert_eq!(h.join().unwrap(), 99);
    }

    #[test]
    fn depth_and_high_watermark_track_occupancy() {
        let (tx, rx) = ring::<u32>(8);
        assert_eq!(rx.depth(), 0);
        assert_eq!(rx.high_watermark(), 0);
        for i in 0..5 {
            tx.try_send(i).unwrap();
        }
        assert_eq!(rx.depth(), 5);
        assert_eq!(rx.high_watermark(), 5);
        for _ in 0..5 {
            rx.try_recv().unwrap();
        }
        // Depth falls with consumption; the high watermark is sticky.
        assert_eq!(rx.depth(), 0);
        assert_eq!(rx.high_watermark(), 5);
        tx.try_send(9).unwrap();
        assert_eq!(rx.depth(), 1);
        assert_eq!(rx.high_watermark(), 5);

        // The type-erased probe agrees and outlives the handles.
        let probe = tx.probe();
        assert_eq!(probe.depth(), 1);
        assert_eq!(probe.high_watermark(), 5);
        assert_eq!(probe.capacity(), 8);
        drop(tx);
        drop(rx);
        assert_eq!(probe.high_watermark(), 5);
    }

    #[test]
    fn unconsumed_values_are_dropped_once() {
        struct Counted(Arc<AtomicUsize>);
        impl Drop for Counted {
            fn drop(&mut self) {
                self.0.fetch_add(1, Ordering::SeqCst);
            }
        }
        let drops = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = ring::<Counted>(8);
        for _ in 0..5 {
            tx.try_send(Counted(drops.clone())).unwrap();
        }
        drop(rx.try_recv()); // one consumed (and dropped)
        drop(rx);
        drop(tx);
        assert_eq!(drops.load(Ordering::SeqCst), 5);
    }
}
