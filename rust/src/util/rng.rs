//! Deterministic pseudo-random numbers and the distributions the paper's
//! workloads need (Poisson / Gamma / Zipf / log-normal).
//!
//! The offline registry has no `rand` crate, so this is a small,
//! well-tested PCG64 (XSL-RR 128/64) plus SplitMix64 seeding — the same
//! generators `rand_pcg` ships. Everything in the repo that needs
//! randomness takes an explicit `&mut Rng` so experiments are exactly
//! reproducible from a seed.

/// SplitMix64 — used to expand a `u64` seed into PCG state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG64 (XSL-RR 128/64). Deterministic, fast, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u128,
    inc: u128,
}

impl Rng {
    const MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

    /// Create a generator from a 64-bit seed (stream derived from seed).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm);
        let s1 = splitmix64(&mut sm);
        let i0 = splitmix64(&mut sm);
        let i1 = splitmix64(&mut sm);
        let mut rng = Rng {
            state: 0,
            inc: (((i0 as u128) << 64 | i1 as u128) << 1) | 1,
        };
        rng.state = rng.state.wrapping_mul(Self::MULT).wrapping_add(rng.inc);
        rng.state = rng
            .state
            .wrapping_add((s0 as u128) << 64 | s1 as u128);
        rng.state = rng.state.wrapping_mul(Self::MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent child generator (per-model streams etc.).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(Self::MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `(0, 1]` — safe as a log() argument.
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        1.0 - self.f64()
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's nearly-divisionless method.
        debug_assert!(n > 0);
        let mut m = (self.next_u64() as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                m = (self.next_u64() as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi)` (f64).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Standard exponential (mean 1) via inverse transform.
    #[inline]
    pub fn exp1(&mut self) -> f64 {
        -self.f64_open().ln()
    }

    /// Standard normal via Marsaglia polar method.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Gamma(shape k, scale θ) via Marsaglia–Tsang; valid for any k > 0.
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        debug_assert!(shape > 0.0 && scale > 0.0);
        if shape < 1.0 {
            // Boost: Gamma(k) = Gamma(k+1) * U^(1/k).
            let g = self.gamma(shape + 1.0, 1.0);
            return g * self.f64_open().powf(1.0 / shape) * scale;
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64_open();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln())
            {
                return d * v3 * scale;
            }
        }
    }

    /// Log-normal with the given parameters of the underlying normal.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }
}

/// Zipf sampler over `{0, .., n-1}` with exponent `s` (popularity skew).
///
/// Precomputes the CDF — sampling is a binary search, good enough for the
/// workload generator (n is the number of models, ≤ a few thousand).
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut total = 0.0;
        for i in 1..=n {
            total += 1.0 / (i as f64).powf(s);
            cdf.push(total);
        }
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Zipf { cdf }
    }

    /// Normalized weight of rank `i`.
    pub fn weight(&self, i: usize) -> f64 {
        if i == 0 {
            self.cdf[0]
        } else {
            self.cdf[i] - self.cdf[i - 1]
        }
    }

    pub fn weights(&self) -> Vec<f64> {
        (0..self.cdf.len()).map(|i| self.weight(i)).collect()
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Rng::new(1);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            let y = rng.f64_open();
            assert!(y > 0.0 && y <= 1.0);
        }
    }

    #[test]
    fn below_is_uniform() {
        let mut rng = Rng::new(2);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[rng.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket {c}");
        }
    }

    #[test]
    fn exp_mean() {
        let mut rng = Rng::new(3);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| rng.exp1()).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(4);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn gamma_moments() {
        // Gamma(k, θ): mean kθ, var kθ².  Check a bursty shape (0.1) and a
        // Poisson-equivalent shape (1.0) — the two ends of Table 1.
        for &(k, theta) in &[(0.1, 10.0), (0.5, 2.0), (1.0, 1.0), (4.0, 0.25)] {
            let mut rng = Rng::new(5);
            let n = 300_000;
            let xs: Vec<f64> = (0..n).map(|_| rng.gamma(k, theta)).collect();
            let mean = xs.iter().sum::<f64>() / n as f64;
            let var =
                xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
            assert!(
                (mean - k * theta).abs() / (k * theta) < 0.05,
                "k={k} mean {mean}"
            );
            assert!(
                (var - k * theta * theta).abs() / (k * theta * theta) < 0.15,
                "k={k} var {var}"
            );
        }
    }

    #[test]
    fn zipf_is_skewed_and_normalized() {
        let z = Zipf::new(100, 0.9);
        let w = z.weights();
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(w[0] > w[10] && w[10] > w[99]);
        let mut rng = Rng::new(6);
        let mut counts = vec![0u32; 100];
        for _ in 0..100_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[50]);
        let observed = counts[0] as f64 / 100_000.0;
        assert!((observed - w[0]).abs() < 0.01);
    }

    #[test]
    fn lognormal_median() {
        let mut rng = Rng::new(9);
        let n = 100_000;
        let mut xs: Vec<f64> = (0..n).map(|_| rng.lognormal(2.0, 0.5)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[n / 2];
        assert!((median - 2.0f64.exp()).abs() / 2.0f64.exp() < 0.03);
    }

    #[test]
    fn shuffle_permutes() {
        let mut rng = Rng::new(10);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
