//! Sync shim the lock-free fabric is built on — the seam `symphony
//! check` instruments.
//!
//! `util/ring.rs` (the Vyukov MPSC ring and the Dekker [`Parker`]
//! protocol) and `coordinator/router.rs::FreeHints` do not touch
//! `std::sync::atomic` directly any more: every atomic load/store/RMW,
//! every SeqCst fence, every Mutex/Condvar edge, and every access to an
//! `UnsafeCell` slot payload goes through a [`Fabric`]. Two fabrics
//! exist:
//!
//! * [`RealFabric`] — the normal build. Every operation delegates
//!   straight to `std::sync::atomic::AtomicUsize` / `Mutex` / `Condvar`
//!   and the cell hooks are empty `()` tokens. All methods are
//!   `#[inline]` one-liners over concrete types, so monomorphization
//!   erases the shim completely: the compiled ring is the same code it
//!   was before the seam existed.
//! * `check::virt::VirtFabric` — the model checker. Every operation
//!   traps into a cooperative scheduler that owns a virtual memory
//!   (TSO store buffers + vector clocks), so `symphony check` can
//!   enumerate every interleaving of the *real* protocol code up to a
//!   preemption bound.
//!
//! Design note: the ISSUE sketch words this seam as a
//! `cfg(symphony_check)` switch. A cfg switch cannot satisfy the
//! tier-1 mirror test (`check_models_pass` must run under a plain
//! `cargo test`, which never passes custom `--cfg` flags), so the seam
//! is a generic parameter instead: `Parker` / `RingSender` /
//! `FreeHints` are type aliases instantiating the generic protocol
//! code at [`RealFabric`], and the checker instantiates the same code
//! at `VirtFabric`. Same single copy of the protocol either way — the
//! property the cfg switch was after.
//!
//! The shim is deliberately *narrow*: exactly the operations the
//! fabric's protocols use, nothing speculative. `usize` atomics only
//! (the fabric has no other kind), and the Mutex/Condvar pair is
//! abstracted as a [`ShimBlocker`] — the two composite operations the
//! `Parker` needs — rather than as raw guard-returning lock methods,
//! which keeps the trait object-safe-simple and keeps the lock
//! discipline (CAS under the lock, notify under the lock) inside one
//! audited implementation per fabric.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, PoisonError};
use std::time::Instant;

use super::sync::relock;

/// The operations the fabric performs on a `usize` atomic. Implemented
/// by `std::sync::atomic::AtomicUsize` (delegation) and by the
/// checker's virtual atomic (trap into the scheduler).
pub trait ShimAtomic: Send + Sync {
    fn load(&self, order: Ordering) -> usize;
    fn store(&self, v: usize, order: Ordering);
    fn swap(&self, v: usize, order: Ordering) -> usize;
    fn compare_exchange(
        &self,
        current: usize,
        new: usize,
        success: Ordering,
        failure: Ordering,
    ) -> Result<usize, usize>;
    fn compare_exchange_weak(
        &self,
        current: usize,
        new: usize,
        success: Ordering,
        failure: Ordering,
    ) -> Result<usize, usize>;
    fn fetch_add(&self, v: usize, order: Ordering) -> usize;
    fn fetch_sub(&self, v: usize, order: Ordering) -> usize;
    /// CAS-loop update, mirroring `AtomicUsize::fetch_update`. Takes
    /// `&mut dyn FnMut` (not a generic) so the trait stays simple for
    /// both implementations.
    fn fetch_update(
        &self,
        set_order: Ordering,
        fetch_order: Ordering,
        f: &mut dyn FnMut(usize) -> Option<usize>,
    ) -> Result<usize, usize>;
}

impl ShimAtomic for AtomicUsize {
    #[inline]
    fn load(&self, order: Ordering) -> usize {
        AtomicUsize::load(self, order)
    }
    #[inline]
    fn store(&self, v: usize, order: Ordering) {
        AtomicUsize::store(self, v, order)
    }
    #[inline]
    fn swap(&self, v: usize, order: Ordering) -> usize {
        AtomicUsize::swap(self, v, order)
    }
    #[inline]
    fn compare_exchange(
        &self,
        current: usize,
        new: usize,
        success: Ordering,
        failure: Ordering,
    ) -> Result<usize, usize> {
        AtomicUsize::compare_exchange(self, current, new, success, failure)
    }
    #[inline]
    fn compare_exchange_weak(
        &self,
        current: usize,
        new: usize,
        success: Ordering,
        failure: Ordering,
    ) -> Result<usize, usize> {
        AtomicUsize::compare_exchange_weak(self, current, new, success, failure)
    }
    #[inline]
    fn fetch_add(&self, v: usize, order: Ordering) -> usize {
        AtomicUsize::fetch_add(self, v, order)
    }
    #[inline]
    fn fetch_sub(&self, v: usize, order: Ordering) -> usize {
        AtomicUsize::fetch_sub(self, v, order)
    }
    #[inline]
    fn fetch_update(
        &self,
        set_order: Ordering,
        fetch_order: Ordering,
        f: &mut dyn FnMut(usize) -> Option<usize>,
    ) -> Result<usize, usize> {
        AtomicUsize::fetch_update(self, set_order, fetch_order, f)
    }
}

/// The Mutex+Condvar edge of the `Parker`, reduced to the two
/// composite operations the wake-not-lost protocol needs. Keeping the
/// lock inside the implementation (instead of exposing guards) means
/// the protocol-critical discipline — the waiter re-checks its
/// condition under the same lock the waker CASes under — cannot be
/// violated by a call-site refactor.
pub trait ShimBlocker: Send + Sync {
    fn new() -> Self;
    /// Lock; while `keep_waiting()` holds, wait on the condvar
    /// (bounded by `deadline`; `None` = forever); unlock. Spurious
    /// returns are allowed — callers re-check state afterwards.
    fn block_while(&self, keep_waiting: &mut dyn FnMut() -> bool, deadline: Option<Instant>);
    /// Run `update` under the lock; if it returns true, notify one
    /// waiter (still determining the wake before the lock is
    /// released).
    fn update_and_notify(&self, update: &mut dyn FnMut() -> bool);
}

/// [`ShimBlocker`] over a real `Mutex<()>` + `Condvar`, with the same
/// poison-recovery policy as `util::sync::relock`: a panicked peer
/// must not cascade into the drain loops.
pub struct RealBlocker {
    lock: Mutex<()>,
    cv: Condvar,
}

impl ShimBlocker for RealBlocker {
    fn new() -> Self {
        RealBlocker {
            lock: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    fn block_while(&self, keep_waiting: &mut dyn FnMut() -> bool, deadline: Option<Instant>) {
        let mut g = relock(&self.lock);
        while keep_waiting() {
            match deadline {
                None => {
                    g = self.cv.wait(g).unwrap_or_else(PoisonError::into_inner);
                }
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        break;
                    }
                    g = match self.cv.wait_timeout(g, d - now) {
                        Ok((g, _)) => g,
                        Err(p) => p.into_inner().0,
                    };
                }
            }
        }
    }

    fn update_and_notify(&self, update: &mut dyn FnMut() -> bool) {
        let _g = relock(&self.lock);
        if update() {
            self.cv.notify_one();
        }
    }
}

/// One fabric = one coherent implementation of everything the ring,
/// the `Parker`, and `FreeHints` need from the platform.
pub trait Fabric: Sized + Send + Sync + 'static {
    type Atomic: ShimAtomic;
    type Blocker: ShimBlocker;
    /// Per-cell identity for instrumented `UnsafeCell` payload
    /// accesses. `()` in real builds (zero cost); a unique-address
    /// token under the checker, keying the happens-before race
    /// detector.
    type CellToken: Send + Sync;

    fn atomic(v: usize) -> Self::Atomic;
    fn blocker() -> Self::Blocker;
    fn cell_token() -> Self::CellToken;
    /// Record a read of the cell `tok` guards (the consumer side of a
    /// slot handoff). No-op in real builds.
    fn cell_read(tok: &Self::CellToken);
    /// Record a write of the cell `tok` guards (the producer side).
    /// No-op in real builds.
    fn cell_write(tok: &Self::CellToken);
    fn fence_seqcst();
    /// `Waiter` budget as (spin rounds, yield rounds). The checker
    /// returns (0, 0): under exhaustive schedule exploration a spin
    /// ladder is pure state-space, so virtual receivers go straight to
    /// the park edge — which is the protocol under test.
    fn spin_budget() -> (u32, u32);
    /// Whether rings maintain their advisory occupancy gauges
    /// (depth / high-watermark, surfaced on `/metrics`). On for
    /// production; the checker turns them off — the protocol never
    /// reads a gauge, so its atomics would be pure state-space.
    fn track_gauges() -> bool {
        true
    }
}

/// The production fabric: plain std primitives, no instrumentation.
pub struct RealFabric;

impl Fabric for RealFabric {
    type Atomic = AtomicUsize;
    type Blocker = RealBlocker;
    type CellToken = ();

    #[inline]
    fn atomic(v: usize) -> AtomicUsize {
        AtomicUsize::new(v)
    }
    #[inline]
    fn blocker() -> RealBlocker {
        RealBlocker::new()
    }
    #[inline]
    fn cell_token() {}
    #[inline]
    fn cell_read(_tok: &()) {}
    #[inline]
    fn cell_write(_tok: &()) {}
    #[inline]
    fn fence_seqcst() {
        std::sync::atomic::fence(Ordering::SeqCst);
    }
    #[inline]
    fn spin_budget() -> (u32, u32) {
        // The PR-7 numbers: 64 spin rounds (escalating `spin_loop`
        // hints) then 32 yields before a receiver truly parks.
        (64, 32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn real_atomic_delegates() {
        let a = RealFabric::atomic(5);
        assert_eq!(ShimAtomic::load(&a, Ordering::SeqCst), 5);
        ShimAtomic::store(&a, 9, Ordering::SeqCst);
        assert_eq!(ShimAtomic::swap(&a, 1, Ordering::SeqCst), 9);
        assert_eq!(
            ShimAtomic::compare_exchange(&a, 1, 2, Ordering::SeqCst, Ordering::SeqCst),
            Ok(1)
        );
        assert_eq!(ShimAtomic::fetch_add(&a, 3, Ordering::SeqCst), 2);
        assert_eq!(ShimAtomic::fetch_sub(&a, 1, Ordering::SeqCst), 5);
        assert_eq!(
            ShimAtomic::fetch_update(&a, Ordering::SeqCst, Ordering::SeqCst, &mut |c| c
                .checked_sub(4)),
            Ok(4)
        );
        assert_eq!(ShimAtomic::load(&a, Ordering::SeqCst), 0);
    }

    #[test]
    fn real_blocker_wakes_a_waiter() {
        let b = Arc::new(RealFabric::blocker());
        let flag = Arc::new(AtomicUsize::new(0));
        let (b2, f2) = (b.clone(), flag.clone());
        let h = std::thread::spawn(move || {
            b2.block_while(&mut || f2.load(Ordering::SeqCst) == 0, None);
        });
        std::thread::sleep(Duration::from_millis(20));
        b.update_and_notify(&mut || {
            flag.store(1, Ordering::SeqCst);
            true
        });
        h.join().unwrap();
        assert_eq!(flag.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn real_blocker_deadline_expires() {
        let b = RealFabric::blocker();
        let t0 = Instant::now();
        b.block_while(&mut || true, Some(Instant::now() + Duration::from_millis(15)));
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }
}
