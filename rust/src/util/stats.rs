//! Summary statistics used by the metrics layer and every bench:
//! exact percentiles, histograms, CDF dumps, streaming mean/variance.

/// Exact percentile over a sample (sorts a copy; fine at experiment scale).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p), "percentile {p}");
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut xs = samples.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&xs, p)
}

/// Percentile over an already-sorted sample (nearest-rank with linear
/// interpolation, the same convention numpy's default uses).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

pub fn mean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    samples.iter().sum::<f64>() / samples.len() as f64
}

/// Welford streaming mean/variance — used where storing samples is too
/// expensive (e.g. per-event accounting in the 5880-config sweep).
#[derive(Clone, Debug, Default)]
pub struct Streaming {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Streaming {
    pub fn new() -> Self {
        Streaming {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Integer-bucket histogram (e.g. batch-size distributions, Fig 1).
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
}

impl Histogram {
    pub fn new() -> Self {
        Histogram::default()
    }

    #[inline]
    pub fn add(&mut self, bucket: usize) {
        self.add_n(bucket, 1);
    }

    #[inline]
    pub fn add_n(&mut self, bucket: usize, n: u64) {
        if bucket >= self.counts.len() {
            self.counts.resize(bucket + 1, 0);
        }
        self.counts[bucket] += n;
        self.total += n;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn count(&self, bucket: usize) -> u64 {
        self.counts.get(bucket).copied().unwrap_or(0)
    }

    pub fn buckets(&self) -> &[u64] {
        &self.counts
    }

    /// Smallest bucket b such that cumulative fraction ≥ q (q in [0,1]).
    pub fn quantile(&self, q: f64) -> usize {
        if self.total == 0 {
            return 0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut cum = 0;
        for (b, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target.max(1) {
                return b;
            }
        }
        self.counts.len().saturating_sub(1)
    }

    pub fn median(&self) -> usize {
        self.quantile(0.5)
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let sum: u64 = self
            .counts
            .iter()
            .enumerate()
            .map(|(b, &c)| b as u64 * c)
            .sum();
        sum as f64 / self.total as f64
    }

    /// `(bucket, fraction)` pairs for non-empty buckets — CDF-plot input.
    pub fn cdf(&self) -> Vec<(usize, f64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                cum += c;
                out.push((b, cum as f64 / self.total as f64));
            }
        }
        out
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (b, &c) in other.counts.iter().enumerate() {
            if c > 0 {
                self.add_n(b, c);
            }
        }
    }
}

/// Dump a sample's CDF at fixed evaluation points (for figure output).
pub fn cdf_points(samples: &[f64], points: usize) -> Vec<(f64, f64)> {
    if samples.is_empty() {
        return Vec::new();
    }
    let mut xs = samples.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (0..=points)
        .map(|i| {
            let q = i as f64 / points as f64;
            (percentile_sorted(&xs, q * 100.0), q)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_basic() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-9);
        assert!((percentile(&xs, 99.0) - 99.01).abs() < 1e-9);
    }

    #[test]
    fn percentile_edge_cases() {
        assert!(percentile(&[], 50.0).is_nan());
        assert_eq!(percentile(&[3.0], 99.0), 3.0);
    }

    #[test]
    fn streaming_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 10.0).collect();
        let mut s = Streaming::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - mean(&xs)).abs() < 1e-9);
        let batch_var = xs.iter().map(|x| (x - mean(&xs)).powi(2)).sum::<f64>()
            / (xs.len() - 1) as f64;
        assert!((s.var() - batch_var).abs() < 1e-6);
        assert_eq!(s.count(), 1000);
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::new();
        for b in 1..=10 {
            h.add_n(b, 10);
        }
        assert_eq!(h.total(), 100);
        assert_eq!(h.median(), 5);
        assert_eq!(h.quantile(1.0), 10);
        assert!((h.mean() - 5.5).abs() < 1e-9);
        let cdf = h.cdf();
        assert_eq!(cdf.first().unwrap().0, 1);
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::new();
        a.add(1);
        let mut b = Histogram::new();
        b.add(2);
        b.add(2);
        a.merge(&b);
        assert_eq!(a.total(), 3);
        assert_eq!(a.count(2), 2);
    }
}
