//! Summary statistics used by the metrics layer and every bench:
//! exact percentiles, histograms, CDF dumps, streaming mean/variance.

/// Exact percentile over a sample (sorts a copy; fine at experiment scale).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p), "percentile {p}");
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut xs = samples.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&xs, p)
}

/// Percentile over an already-sorted sample (nearest-rank with linear
/// interpolation, the same convention numpy's default uses).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

pub fn mean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    samples.iter().sum::<f64>() / samples.len() as f64
}

/// Welford streaming mean/variance — used where storing samples is too
/// expensive (e.g. per-event accounting in the 5880-config sweep).
#[derive(Clone, Debug, Default)]
pub struct Streaming {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Streaming {
    pub fn new() -> Self {
        Streaming {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Integer-bucket histogram (e.g. batch-size distributions, Fig 1).
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
}

impl Histogram {
    pub fn new() -> Self {
        Histogram::default()
    }

    #[inline]
    pub fn add(&mut self, bucket: usize) {
        self.add_n(bucket, 1);
    }

    #[inline]
    pub fn add_n(&mut self, bucket: usize, n: u64) {
        if bucket >= self.counts.len() {
            self.counts.resize(bucket + 1, 0);
        }
        self.counts[bucket] += n;
        self.total += n;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn count(&self, bucket: usize) -> u64 {
        self.counts.get(bucket).copied().unwrap_or(0)
    }

    pub fn buckets(&self) -> &[u64] {
        &self.counts
    }

    /// Smallest bucket b such that cumulative fraction ≥ q (q in [0,1]).
    pub fn quantile(&self, q: f64) -> usize {
        if self.total == 0 {
            return 0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut cum = 0;
        for (b, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target.max(1) {
                return b;
            }
        }
        self.counts.len().saturating_sub(1)
    }

    pub fn median(&self) -> usize {
        self.quantile(0.5)
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let sum: u64 = self
            .counts
            .iter()
            .enumerate()
            .map(|(b, &c)| b as u64 * c)
            .sum();
        sum as f64 / self.total as f64
    }

    /// `(bucket, fraction)` pairs for non-empty buckets — CDF-plot input.
    pub fn cdf(&self) -> Vec<(usize, f64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                cum += c;
                out.push((b, cum as f64 / self.total as f64));
            }
        }
        out
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (b, &c) in other.counts.iter().enumerate() {
            if c > 0 {
                self.add_n(b, c);
            }
        }
    }
}

/// Log-linear bucketed histogram over `u64` values (µs latencies):
/// [`LOG_SUB_BITS`] sub-buckets per octave, so the relative error of
/// any quantile is bounded by `2^-LOG_SUB_BITS` (~12.5% at 3 bits) —
/// HdrHistogram's layout, sized for the flight recorder's per-hop
/// latency breakdown where ranges span 1 µs queue hops to multi-second
/// stalls and a dense `Histogram` would need millions of buckets.
///
/// Values below `2^LOG_SUB_BITS` are exact (bucket = value); above,
/// bucket index is `(msb - b) * 2^b + (v >> (msb - b))` for
/// `b = LOG_SUB_BITS`, which is contiguous with the linear region and
/// monotone in `v`.
#[derive(Clone, Debug, Default)]
pub struct LogHistogram {
    counts: Vec<u64>,
    total: u64,
}

/// Sub-bucket resolution: 2^3 = 8 sub-buckets per octave.
const LOG_SUB_BITS: u32 = 3;

/// Bucket count covering all of `u64`: 16 exact + (64 - 3) octaves × 8.
const LOG_BUCKETS: usize = ((64 - LOG_SUB_BITS as usize) << LOG_SUB_BITS) + (1 << LOG_SUB_BITS);

fn log_bucket(v: u64) -> usize {
    let b = LOG_SUB_BITS;
    if v < (1 << b) {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros();
    (((msb - b) as usize) << b) + (v >> (msb - b)) as usize
}

/// Smallest value mapping to `bucket` (inverse of [`log_bucket`]).
fn log_bucket_floor(bucket: usize) -> u64 {
    let b = LOG_SUB_BITS as usize;
    if bucket < (1 << b) {
        return bucket as u64;
    }
    // bucket = (msb - b)*2^b + m with m = v >> (msb - b) in
    // [2^b, 2^(b+1)), so bucket >> b = msb - b + 1.
    let shift = (bucket >> b) - 1;
    let m = bucket - (shift << b);
    (m as u64) << shift
}

impl LogHistogram {
    pub fn new() -> Self {
        LogHistogram {
            counts: Vec::new(),
            total: 0,
        }
    }

    pub fn add(&mut self, v: u64) {
        let bucket = log_bucket(v).min(LOG_BUCKETS - 1);
        if self.counts.len() <= bucket {
            self.counts.resize(bucket + 1, 0);
        }
        self.counts[bucket] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Value at quantile `q` in [0, 1]: the floor of the bucket holding
    /// the q-th sample (0 when empty). Within one sub-bucket of the
    /// exact answer by construction.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (bucket, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return log_bucket_floor(bucket);
            }
        }
        log_bucket_floor(self.counts.len().saturating_sub(1))
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        if self.counts.len() < other.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (bucket, &c) in other.counts.iter().enumerate() {
            self.counts[bucket] += c;
        }
        self.total += other.total;
    }
}

/// Dump a sample's CDF at fixed evaluation points (for figure output).
pub fn cdf_points(samples: &[f64], points: usize) -> Vec<(f64, f64)> {
    if samples.is_empty() {
        return Vec::new();
    }
    let mut xs = samples.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (0..=points)
        .map(|i| {
            let q = i as f64 / points as f64;
            (percentile_sorted(&xs, q * 100.0), q)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_basic() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-9);
        assert!((percentile(&xs, 99.0) - 99.01).abs() < 1e-9);
    }

    #[test]
    fn percentile_edge_cases() {
        assert!(percentile(&[], 50.0).is_nan());
        assert_eq!(percentile(&[3.0], 99.0), 3.0);
    }

    #[test]
    fn streaming_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 10.0).collect();
        let mut s = Streaming::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - mean(&xs)).abs() < 1e-9);
        let batch_var = xs.iter().map(|x| (x - mean(&xs)).powi(2)).sum::<f64>()
            / (xs.len() - 1) as f64;
        assert!((s.var() - batch_var).abs() < 1e-6);
        assert_eq!(s.count(), 1000);
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::new();
        for b in 1..=10 {
            h.add_n(b, 10);
        }
        assert_eq!(h.total(), 100);
        assert_eq!(h.median(), 5);
        assert_eq!(h.quantile(1.0), 10);
        assert!((h.mean() - 5.5).abs() < 1e-9);
        let cdf = h.cdf();
        assert_eq!(cdf.first().unwrap().0, 1);
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn log_bucket_is_monotone_and_inverts() {
        let mut last = 0usize;
        for v in 0..4096u64 {
            let b = log_bucket(v);
            assert!(b >= last, "bucket({v}) = {b} < {last}");
            last = b;
            let floor = log_bucket_floor(b);
            assert!(floor <= v, "floor({b}) = {floor} > {v}");
            // Relative bucket width is bounded by 2^-LOG_SUB_BITS.
            assert!(v - floor <= (v >> LOG_SUB_BITS), "v={v} floor={floor}");
        }
        assert_eq!(log_bucket(u64::MAX).min(LOG_BUCKETS - 1), LOG_BUCKETS - 1);
    }

    #[test]
    fn log_histogram_quantiles_within_bucket_error() {
        let mut h = LogHistogram::new();
        for v in 1..=1000u64 {
            h.add(v);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.50);
        let p99 = h.quantile(0.99);
        assert!((440..=500).contains(&p50), "p50 {p50}");
        assert!((870..=990).contains(&p99), "p99 {p99}");
        assert_eq!(h.quantile(0.0).min(1), 1);
        assert!(h.quantile(1.0) <= 1000);
        assert_eq!(LogHistogram::new().quantile(0.99), 0);
    }

    #[test]
    fn log_histogram_merge_sums_counts() {
        let mut a = LogHistogram::new();
        a.add(5);
        a.add(100_000);
        let mut b = LogHistogram::new();
        b.add(5);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.quantile(0.5), 5);
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::new();
        a.add(1);
        let mut b = Histogram::new();
        b.add(2);
        b.add(2);
        a.merge(&b);
        assert_eq!(a.total(), 3);
        assert_eq!(a.count(2), 2);
    }
}
