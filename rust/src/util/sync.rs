//! Small synchronization helpers.

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Lock `m`, recovering the guard if the mutex is poisoned.
///
/// Poisoning means some other thread panicked while holding the guard.
/// For the state these mutexes protect (wire writer handles, ack
/// routing tables, pending-frame queues) the data is still structurally
/// valid after a panic, and propagating the poison would turn one dead
/// session thread into a process-wide cascade — the exact failure mode
/// the wire surface is designed to contain. Recovering is therefore the
/// deliberate policy, not a convenience.
pub fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn relock_recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*relock(&m), 7);
    }
}
