//! Aligned text tables + TSV result files. Every bench prints the paper's
//! rows through this and mirrors them to `results/<id>.tsv`.

use std::fmt::Write as _;
use std::fs;
use std::io::Write as _;
use std::path::Path;

/// A simple column-aligned table with a header row.
#[derive(Clone, Debug)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width != header width"
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "{:<w$}", c, w = widths[i] + 2);
            }
            out.push('\n');
        };
        fmt_row(&mut out, &self.header);
        let total: usize = widths.iter().map(|w| w + 2).sum();
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(&mut out, row);
        }
        out
    }

    /// Print to stdout and mirror to `results/<name>.tsv`.
    pub fn emit(&self, name: &str) {
        print!("{}", self.render());
        if let Err(e) = self.write_tsv(Path::new("results").join(format!("{name}.tsv"))) {
            eprintln!("warn: could not write results/{name}.tsv: {e}");
        }
    }

    pub fn write_tsv<P: AsRef<Path>>(&self, path: P) -> std::io::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            fs::create_dir_all(dir)?;
        }
        let mut f = fs::File::create(path)?;
        writeln!(f, "{}", self.header.join("\t"))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join("\t"))?;
        }
        Ok(())
    }
}

/// Format helpers shared by benches.
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

/// Section banner used by benches so `cargo bench` output reads like the
/// paper's evaluation section.
pub fn banner(title: &str) {
    let line = "=".repeat(title.len() + 8);
    println!("\n{line}\n=== {title} ===\n{line}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["sys", "goodput"]);
        t.row(vec!["symphony".to_string(), "5264".to_string()]);
        t.row(vec!["nexus".to_string(), "4027".to_string()]);
        let s = t.render();
        assert!(s.contains("symphony"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // Columns aligned: "goodput" starts at the same offset everywhere.
        let col = lines[0].find("goodput").unwrap();
        assert_eq!(&lines[2][col..col + 4], "5264");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn tsv_round_trip() {
        let mut t = Table::new(vec!["x", "y"]);
        t.row(vec!["1", "2"]);
        let dir = std::env::temp_dir().join("symphony_table_test");
        let path = dir.join("t.tsv");
        t.write_tsv(&path).unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert_eq!(content, "x\ty\n1\t2\n");
    }
}
