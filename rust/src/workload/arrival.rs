//! Per-model arrival processes.
//!
//! The paper's workloads use Poisson arrivals by default and Gamma
//! inter-arrival distributions with shape 0.1–1.0 for burstiness
//! (Table 1; Γ(1.0) ≡ Poisson). Fig 15 drives rates from a time-varying
//! trace, modeled here as a piecewise-constant rate function.

use crate::core::time::Micros;
use crate::util::rng::Rng;

/// The arrival process of one model's request stream.
#[derive(Clone, Debug)]
pub enum ArrivalKind {
    /// Poisson process at `rate` requests/second.
    Poisson { rate: f64 },
    /// Gamma-distributed inter-arrivals with the given `shape` and mean
    /// `1/rate` (shape < 1 is burstier than Poisson; shape = 1 is
    /// exactly Poisson).
    Gamma { rate: f64, shape: f64 },
    /// Deterministic arrivals every `1/rate` seconds (the §3.3 worked
    /// example's uniform process).
    Uniform { rate: f64 },
    /// Piecewise-constant rate: `(start_time, rate)` segments, sorted.
    /// Sampling uses the rate of the segment containing the current time.
    PiecewiseRate { segments: Vec<(Micros, f64)>, shape: f64 },
    /// Explicit arrival times (unit tests / worked examples).
    Explicit { times: Vec<Micros> },
}

impl ArrivalKind {
    /// Mean rate right now (requests/second).
    pub fn rate_at(&self, now: Micros) -> f64 {
        match self {
            ArrivalKind::Poisson { rate }
            | ArrivalKind::Gamma { rate, .. }
            | ArrivalKind::Uniform { rate } => *rate,
            ArrivalKind::PiecewiseRate { segments, .. } => {
                let mut r = 0.0;
                for &(t, rate) in segments {
                    if t <= now {
                        r = rate;
                    } else {
                        break;
                    }
                }
                r
            }
            ArrivalKind::Explicit { .. } => 0.0,
        }
    }
}

/// Stateful generator of one model's arrival times.
#[derive(Clone, Debug)]
pub struct ArrivalStream {
    kind: ArrivalKind,
    rng: Rng,
    next_explicit: usize,
}

impl ArrivalStream {
    pub fn new(kind: ArrivalKind, rng: Rng) -> Self {
        ArrivalStream {
            kind,
            rng,
            next_explicit: 0,
        }
    }

    /// Time of the next arrival strictly after `now`, or `None` if the
    /// stream is exhausted (explicit) or the rate is zero forever.
    pub fn next_after(&mut self, now: Micros) -> Option<Micros> {
        match &self.kind {
            ArrivalKind::Poisson { rate } => {
                if *rate <= 0.0 {
                    return None;
                }
                Some(now + Micros::from_secs_f64(self.rng.exp1() / rate))
            }
            ArrivalKind::Gamma { rate, shape } => {
                if *rate <= 0.0 {
                    return None;
                }
                // Mean inter-arrival 1/rate => scale = 1/(rate*shape).
                let gap = self.rng.gamma(*shape, 1.0 / (rate * shape));
                Some(now + Micros::from_secs_f64(gap))
            }
            ArrivalKind::Uniform { rate } => {
                if *rate <= 0.0 {
                    return None;
                }
                Some(now + Micros::from_secs_f64(1.0 / rate))
            }
            ArrivalKind::PiecewiseRate { segments, shape } => {
                // Draw from the current segment's rate; if there is no
                // load now, jump to the next segment with load.
                let mut t = now;
                loop {
                    let rate = self.kind_rate_at(t, segments);
                    if rate > 0.0 {
                        let gap = if *shape >= 1.0 {
                            self.rng.exp1() / rate
                        } else {
                            self.rng.gamma(*shape, 1.0 / (rate * shape))
                        };
                        return Some(t + Micros::from_secs_f64(gap));
                    }
                    // Find the next segment start after t.
                    let next = segments.iter().map(|&(s, _)| s).find(|&s| s > t)?;
                    t = next;
                }
            }
            ArrivalKind::Explicit { times } => {
                while self.next_explicit < times.len() {
                    let t = times[self.next_explicit];
                    self.next_explicit += 1;
                    if t >= now {
                        return Some(t);
                    }
                }
                None
            }
        }
    }

    fn kind_rate_at(&self, t: Micros, segments: &[(Micros, f64)]) -> f64 {
        let mut r = 0.0;
        for &(s, rate) in segments {
            if s <= t {
                r = rate;
            } else {
                break;
            }
        }
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mean_rate(kind: ArrivalKind, horizon_s: f64) -> f64 {
        let mut s = ArrivalStream::new(kind, Rng::new(7));
        let horizon = Micros::from_secs_f64(horizon_s);
        let mut t = Micros::ZERO;
        let mut n = 0u64;
        while let Some(next) = s.next_after(t) {
            if next > horizon {
                break;
            }
            t = next;
            n += 1;
        }
        n as f64 / horizon_s
    }

    #[test]
    fn poisson_rate() {
        let r = mean_rate(ArrivalKind::Poisson { rate: 1000.0 }, 20.0);
        assert!((r - 1000.0).abs() / 1000.0 < 0.03, "rate {r}");
    }

    #[test]
    fn gamma_rate_all_shapes() {
        for shape in [0.1, 0.3, 0.7, 1.0] {
            let r = mean_rate(ArrivalKind::Gamma { rate: 500.0, shape }, 30.0);
            assert!((r - 500.0).abs() / 500.0 < 0.06, "shape {shape} rate {r}");
        }
    }

    #[test]
    fn gamma_small_shape_is_burstier() {
        // Burstiness: coefficient of variation of inter-arrival gaps
        // is 1/sqrt(shape) for Gamma.
        let cv = |shape: f64| {
            let mut s = ArrivalStream::new(
                ArrivalKind::Gamma { rate: 1000.0, shape },
                Rng::new(3),
            );
            let mut t = Micros::ZERO;
            let mut gaps = Vec::new();
            for _ in 0..50_000 {
                let n = s.next_after(t).unwrap();
                gaps.push((n - t).as_secs_f64());
                t = n;
            }
            let m = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let v = gaps.iter().map(|g| (g - m) * (g - m)).sum::<f64>()
                / gaps.len() as f64;
            v.sqrt() / m
        };
        let bursty = cv(0.1);
        let poisson = cv(1.0);
        assert!((poisson - 1.0).abs() < 0.1, "cv(1.0) = {poisson}");
        assert!((bursty - (1.0f64 / 0.1).sqrt()).abs() < 0.4, "cv(0.1) = {bursty}");
    }

    #[test]
    fn uniform_is_exact() {
        let mut s = ArrivalStream::new(ArrivalKind::Uniform { rate: 4.0 }, Rng::new(1));
        // Gap = 0.25s each.
        let t1 = s.next_after(Micros::ZERO).unwrap();
        let t2 = s.next_after(t1).unwrap();
        assert_eq!(t1, Micros::from_secs_f64(0.25));
        assert_eq!(t2, Micros::from_secs_f64(0.5));
    }

    #[test]
    fn piecewise_respects_segments() {
        let kind = ArrivalKind::PiecewiseRate {
            segments: vec![
                (Micros::ZERO, 0.0),
                (Micros::from_secs_f64(10.0), 1000.0),
                (Micros::from_secs_f64(20.0), 0.0),
            ],
            shape: 1.0,
        };
        let mut s = ArrivalStream::new(kind, Rng::new(5));
        // No load until t=10s: the first arrival must be after that.
        let first = s.next_after(Micros::ZERO).unwrap();
        assert!(first >= Micros::from_secs_f64(10.0));
        // After t=20s the rate is 0 forever -> None.
        let none = s.next_after(Micros::from_secs_f64(25.0));
        assert!(none.is_none());
    }

    #[test]
    fn explicit_stream() {
        let times = vec![Micros(5), Micros(10), Micros(15)];
        let mut s = ArrivalStream::new(ArrivalKind::Explicit { times }, Rng::new(1));
        assert_eq!(s.next_after(Micros::ZERO), Some(Micros(5)));
        assert_eq!(s.next_after(Micros(6)), Some(Micros(10)));
        assert_eq!(s.next_after(Micros(10)), Some(Micros(15)));
        assert_eq!(s.next_after(Micros(15)), None);
    }
}
