//! Workload generation: per-model arrival processes (Poisson / Gamma
//! burstiness / piecewise-rate traces, §5), popularity skew (uniform /
//! Zipf-0.9), and the synthetic rate trace used by the Fig 15
//! changing-workload experiment.

pub mod arrival;
pub mod spec;
pub mod trace;

pub use arrival::{ArrivalKind, ArrivalStream};
pub use spec::{Popularity, Workload, WorkloadSpec};
