//! Workload specification: a set of models, a popularity split of the
//! aggregate offered rate, and an arrival process per model — merged
//! into one time-ordered request stream for the engine.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::core::profile::ModelSpec;
use crate::core::time::Micros;
use crate::core::types::{ModelId, Request, RequestId};
use crate::util::rng::{Rng, Zipf};
use crate::workload::arrival::{ArrivalKind, ArrivalStream};

/// How the aggregate rate splits across models.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Popularity {
    /// All models equally popular (the paper's default, §3.4).
    Equal,
    /// Zipfian with the given exponent (Fig 11 uses 0.9).
    Zipf(f64),
}

impl Popularity {
    pub fn weights(&self, n: usize) -> Vec<f64> {
        match self {
            Popularity::Equal => vec![1.0 / n as f64; n],
            Popularity::Zipf(s) => Zipf::new(n, *s).weights(),
        }
    }
}

/// Declarative description of a workload.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub models: Vec<ModelSpec>,
    /// Aggregate offered rate (requests/second) across all models.
    pub total_rate: f64,
    pub popularity: Popularity,
    /// Gamma shape of inter-arrivals (1.0 = Poisson).
    pub gamma_shape: f64,
    pub seed: u64,
}

impl WorkloadSpec {
    pub fn new(models: Vec<ModelSpec>, total_rate: f64) -> Self {
        WorkloadSpec {
            models,
            total_rate,
            popularity: Popularity::Equal,
            gamma_shape: 1.0,
            seed: 0,
        }
    }

    pub fn popularity(mut self, p: Popularity) -> Self {
        self.popularity = p;
        self
    }

    pub fn gamma_shape(mut self, shape: f64) -> Self {
        self.gamma_shape = shape;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn rate(mut self, total_rate: f64) -> Self {
        self.total_rate = total_rate;
        self
    }

    /// Per-model rates implied by the popularity split.
    pub fn model_rates(&self) -> Vec<f64> {
        self.popularity
            .weights(self.models.len())
            .into_iter()
            .map(|w| w * self.total_rate)
            .collect()
    }

    /// Materialize the merged request stream.
    pub fn build(&self) -> Workload {
        let mut rng = Rng::new(self.seed);
        let streams = self
            .model_rates()
            .into_iter()
            .enumerate()
            .map(|(i, rate)| {
                let kind = if (self.gamma_shape - 1.0).abs() < 1e-12 {
                    ArrivalKind::Poisson { rate }
                } else {
                    ArrivalKind::Gamma {
                        rate,
                        shape: self.gamma_shape,
                    }
                };
                ArrivalStream::new(kind, rng.fork(i as u64))
            })
            .collect();
        Workload::from_streams(self.models.clone(), streams)
    }
}

/// The merged, time-ordered request stream.
pub struct Workload {
    pub models: Vec<ModelSpec>,
    streams: Vec<ArrivalStream>,
    /// Min-heap of (next_arrival, model index).
    heap: BinaryHeap<Reverse<(Micros, u32)>>,
    next_id: u64,
}

impl Workload {
    pub fn from_streams(models: Vec<ModelSpec>, mut streams: Vec<ArrivalStream>) -> Self {
        assert_eq!(models.len(), streams.len());
        let mut heap = BinaryHeap::new();
        for (i, s) in streams.iter_mut().enumerate() {
            if let Some(t) = s.next_after(Micros::ZERO) {
                heap.push(Reverse((t, i as u32)));
            }
        }
        Workload {
            models,
            streams,
            heap,
            next_id: 0,
        }
    }

    /// Build a workload from explicit per-model arrival times (worked
    /// examples, Fig 4/5).
    pub fn explicit(models: Vec<ModelSpec>, times: Vec<Vec<Micros>>) -> Self {
        let streams = times
            .into_iter()
            .map(|t| ArrivalStream::new(ArrivalKind::Explicit { times: t }, Rng::new(0)))
            .collect();
        Workload::from_streams(models, streams)
    }

    /// Time of the next request without consuming it.
    pub fn peek_time(&self) -> Option<Micros> {
        self.heap.peek().map(|Reverse((t, _))| *t)
    }

    /// Consume and return the next request (deadline = arrival + SLO).
    pub fn next_request(&mut self) -> Option<Request> {
        let Reverse((t, m)) = self.heap.pop()?;
        if let Some(next) = self.streams[m as usize].next_after(t) {
            debug_assert!(next >= t);
            // Enforce strict progress so zero gaps cannot live-lock.
            let next = if next == t { Micros(t.0 + 1) } else { next };
            self.heap.push(Reverse((next, m)));
        }
        let id = RequestId(self.next_id);
        self.next_id += 1;
        Some(Request {
            id,
            model: ModelId(m),
            arrival: t,
            deadline: t + self.models[m as usize].slo,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::model_zoo::resnet_like_variants;
    use crate::core::model_zoo::GpuKind;

    #[test]
    fn merged_stream_is_time_ordered() {
        let models = resnet_like_variants(4, 50.0, GpuKind::Gtx1080Ti);
        let mut w = WorkloadSpec::new(models, 2000.0).seed(3).build();
        let mut last = Micros::ZERO;
        let mut counts = [0u32; 4];
        for _ in 0..5000 {
            let r = w.next_request().unwrap();
            assert!(r.arrival >= last);
            assert_eq!(r.deadline, r.arrival + Micros::from_millis_f64(50.0));
            counts[r.model.0 as usize] += 1;
            last = r.arrival;
        }
        // Equal popularity: each model ~1250.
        for c in counts {
            assert!((900..1600).contains(&c), "count {c}");
        }
    }

    #[test]
    fn zipf_popularity_skews_counts() {
        let models = resnet_like_variants(10, 50.0, GpuKind::Gtx1080Ti);
        let mut w = WorkloadSpec::new(models, 5000.0)
            .popularity(Popularity::Zipf(0.9))
            .seed(11)
            .build();
        let mut counts = [0u32; 10];
        for _ in 0..20_000 {
            counts[w.next_request().unwrap().model.0 as usize] += 1;
        }
        assert!(counts[0] > 2 * counts[9], "{counts:?}");
    }

    #[test]
    fn request_ids_unique_and_sequential() {
        let models = resnet_like_variants(2, 20.0, GpuKind::Gtx1080Ti);
        let mut w = WorkloadSpec::new(models, 100.0).build();
        for i in 0..100 {
            assert_eq!(w.next_request().unwrap().id, RequestId(i));
        }
    }

    #[test]
    fn explicit_workload_matches_fig4_example() {
        // §3.3: R_i arrives at t = 0.75 * (i-1) time units (ms here).
        let models = vec![crate::core::profile::ModelSpec::new("m", 1.0, 5.0, 12.0)];
        let times: Vec<Micros> = (0..16)
            .map(|i| Micros::from_millis_f64(0.75 * i as f64))
            .collect();
        let mut w = Workload::explicit(models, vec![times]);
        let r1 = w.next_request().unwrap();
        assert_eq!(r1.arrival, Micros::ZERO);
        assert_eq!(r1.deadline, Micros::from_millis_f64(12.0));
        let r2 = w.next_request().unwrap();
        assert_eq!(r2.arrival, Micros(750));
    }
}
