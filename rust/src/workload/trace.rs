//! Synthetic time-varying rate traces for the Fig 15 changing-workload
//! experiment. The paper derives per-model rates from 150 hours of
//! video; we synthesize traces with the same qualitative structure — a
//! slow diurnal swing, per-model phase offsets, and occasional bursts —
//! as piecewise-constant rate segments (DESIGN.md §3).

use crate::core::time::Micros;
use crate::util::rng::Rng;

/// Parameters of a synthetic diurnal+burst trace.
#[derive(Clone, Debug)]
pub struct TraceSpec {
    /// Experiment duration.
    pub duration: Micros,
    /// Rate-segment granularity.
    pub segment: Micros,
    /// Mean rate of the model's trace (requests/second).
    pub mean_rate: f64,
    /// Peak-to-trough swing as a fraction of the mean (0..1).
    pub swing: f64,
    /// Diurnal period.
    pub period: Micros,
    /// Phase offset (per-model decorrelation).
    pub phase: f64,
    /// Probability that a segment is a burst.
    pub burst_prob: f64,
    /// Burst multiplier applied to the segment rate.
    pub burst_mult: f64,
}

impl TraceSpec {
    pub fn new(duration: Micros, mean_rate: f64) -> Self {
        TraceSpec {
            duration,
            segment: Micros::from_secs_f64(10.0),
            mean_rate,
            swing: 0.6,
            period: Micros::from_secs_f64(600.0),
            phase: 0.0,
            burst_prob: 0.02,
            burst_mult: 2.5,
        }
    }

    pub fn phase(mut self, phase: f64) -> Self {
        self.phase = phase;
        self
    }

    /// Generate the `(start, rate)` segments.
    pub fn generate(&self, rng: &mut Rng) -> Vec<(Micros, f64)> {
        let mut segments = Vec::new();
        let mut t = Micros::ZERO;
        while t < self.duration {
            let x = t.as_secs_f64() / self.period.as_secs_f64();
            let diurnal =
                1.0 + self.swing * (2.0 * std::f64::consts::PI * (x + self.phase)).sin();
            let mut rate = self.mean_rate * diurnal.max(0.05);
            if rng.f64() < self.burst_prob {
                rate *= self.burst_mult;
            }
            segments.push((t, rate));
            t += self.segment;
        }
        segments
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_mean_close_to_spec() {
        let spec = TraceSpec::new(Micros::from_secs_f64(1200.0), 100.0);
        let mut rng = Rng::new(9);
        let segs = spec.generate(&mut rng);
        assert_eq!(segs.len(), 120);
        let mean: f64 = segs.iter().map(|&(_, r)| r).sum::<f64>() / segs.len() as f64;
        // Bursts push the mean slightly above 100.
        assert!((95.0..125.0).contains(&mean), "mean {mean}");
    }

    #[test]
    fn trace_swings() {
        let spec = TraceSpec::new(Micros::from_secs_f64(1200.0), 100.0);
        let mut rng = Rng::new(10);
        let segs = spec.generate(&mut rng);
        let max = segs.iter().map(|&(_, r)| r).fold(0.0, f64::max);
        let min = segs.iter().map(|&(_, r)| r).fold(f64::MAX, f64::min);
        assert!(max > 140.0, "max {max}");
        assert!(min < 60.0, "min {min}");
    }

    #[test]
    fn phases_decorrelate() {
        let mut rng = Rng::new(11);
        let a = TraceSpec::new(Micros::from_secs_f64(600.0), 100.0)
            .phase(0.0)
            .generate(&mut rng);
        let b = TraceSpec::new(Micros::from_secs_f64(600.0), 100.0)
            .phase(0.5)
            .generate(&mut rng);
        // Opposite phases: where a is high, b is low.
        let corr: f64 = a
            .iter()
            .zip(&b)
            .map(|(&(_, x), &(_, y))| (x - 100.0) * (y - 100.0))
            .sum();
        assert!(corr < 0.0, "corr {corr}");
    }
}
