//! Allocation-freedom proof for the deferred scheduler's steady state
//! (§Perf): a counting `#[global_allocator]` wraps the system allocator
//! and the test asserts that after warm-up, driving `on_request` (both
//! the deferral path and the immediate-dispatch path) performs **zero**
//! allocations — and that the flight recorder's *disabled* taps add
//! none on top (the obs contract: untraced runs pay one relaxed load
//! and a predictable branch per tap, nothing else). This file
//! deliberately contains a single `#[test]` so no concurrent test
//! thread can perturb the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use symphony::core::profile::LatencyProfile;
use symphony::core::time::Micros;
use symphony::core::types::{GpuId, ModelId, Request, RequestId};
use symphony::obs::trace::{self, Stage};
use symphony::scheduler::deferred::{DeferredConfig, DeferredScheduler};
use symphony::scheduler::{Command, Scheduler};

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Immediate-dispatch cycle: deadline leaves room for exactly b=1, so
/// `exec = now` and every arrival dispatches on the spot; the GPU is
/// handed back before the next arrival. Exercises plan → take_list →
/// `Command::Dispatch` (inline `ReqList`) → bitset free-set churn.
fn drive_dispatch(s: &mut DeferredScheduler, out: &mut Vec<Command>, i: u64) {
    let t = Micros(i * 10_000);
    out.clear();
    s.on_request(
        Request {
            id: RequestId(i),
            model: ModelId(0),
            arrival: t,
            // ℓ(1) = 6 ms exactly: b=1 fits, frontrun < now ⇒ dispatch now.
            deadline: t + Micros(6_000),
        },
        t,
        out,
    );
    assert!(
        out.iter().any(|c| matches!(c, Command::Dispatch { .. })),
        "expected immediate dispatch at i={i}: {out:?}"
    );
    out.clear();
    s.on_gpu_free(GpuId(0), t + Micros(6_001), out);
}

/// Deferral cycle: a far deadline (10 s — the window never opens within
/// the test) and a batch cap — once the candidate reaches the cap its
/// window stops moving, so steady-state arrivals hit the
/// unchanged-candidate shortcut (queue push + integer planning only).
/// Exercises plan_len with the memoized shedding target.
fn drive_defer(s: &mut DeferredScheduler, out: &mut Vec<Command>, i: u64) {
    let t = Micros(i * 250);
    out.clear();
    s.on_request(
        Request {
            id: RequestId(i),
            model: ModelId(0),
            arrival: t,
            deadline: t + Micros(10_000_000),
        },
        t,
        out,
    );
}

#[test]
fn steady_state_on_request_is_allocation_free() {
    let profile = LatencyProfile::new(1.0, 5.0);

    // Phase 1: immediate-dispatch steady state.
    {
        let mut s = DeferredScheduler::new(vec![profile], 1, DeferredConfig::default());
        let mut out: Vec<Command> = Vec::with_capacity(64);
        for i in 0..256 {
            drive_dispatch(&mut s, &mut out, i);
        }
        let before = allocs();
        for i in 256..1_256 {
            drive_dispatch(&mut s, &mut out, i);
        }
        let delta = allocs() - before;
        assert_eq!(
            delta, 0,
            "immediate-dispatch steady state allocated {delta} times over 1000 requests"
        );
    }

    // Phase 2: deferred steady state (candidate parked behind its
    // frontrun timer, batch capped).
    {
        let cfg = DeferredConfig {
            max_batch: 4,
            ..DeferredConfig::default()
        };
        let mut s = DeferredScheduler::new(vec![profile], 1, cfg);
        let mut out: Vec<Command> = Vec::with_capacity(64);
        // Warm-up grows the model queue past the measured window's needs
        // (VecDeque doubles at powers of two: 1500 pushes leave capacity
        // 2048, and the 400 measured pushes stay below it).
        for i in 0..1_500 {
            drive_defer(&mut s, &mut out, i);
        }
        let before = allocs();
        for i in 1_500..1_900 {
            drive_defer(&mut s, &mut out, i);
        }
        let delta = allocs() - before;
        assert_eq!(
            delta, 0,
            "deferred steady state allocated {delta} times over 400 requests"
        );
    }

    // Phase 3: disabled flight-recorder taps. No trace session is
    // installed in this process, so every tap must short-circuit on the
    // sampling word — zero allocations across every stage of both tap
    // kinds.
    {
        assert!(!trace::enabled(), "no session installed in this test");
        let before = allocs();
        for i in 0..10_000u64 {
            trace::req_event(Stage::Submit, RequestId(i));
            trace::req_event(Stage::IngestBin, RequestId(i));
            trace::req_event(Stage::WorkerRecv, RequestId(i));
            trace::req_event(Stage::Dispatch, RequestId(i));
            trace::req_event(Stage::Complete, RequestId(i));
            trace::model_event(Stage::CandReg, ModelId((i % 7) as u32));
            trace::model_event(Stage::RankGrant, ModelId((i % 7) as u32));
        }
        let delta = allocs() - before;
        assert_eq!(
            delta, 0,
            "disabled trace taps allocated {delta} times over 70k events"
        );
    }
}
