//! Integration: the autoscaling loop on a changing workload (Fig 15)
//! and the partitioning solver at paper scale (Fig 16 / Appendix A).

use std::time::Duration;

use symphony::partition;
use symphony::util::rng::Rng;

/// Fig 15 (scaled down): the autoscaler tracks a diurnal load — GPU
/// count falls in troughs and rises at peaks, and the bad rate stays
/// low in underload.
#[test]
fn autoscaler_tracks_load() {
    let table = symphony::harness::experiments::fig15_autoscale(180.0, 64);
    // Parse back the rows (t, offered, gpus, bad, delta).
    let text = table.render();
    let mut rows: Vec<(f64, f64, usize, f64)> = Vec::new();
    for line in text.lines().skip(2) {
        let cols: Vec<&str> = line.split_whitespace().collect();
        if cols.len() >= 5 {
            let t: f64 = cols[0].parse().unwrap();
            let offered: f64 = cols[1].parse().unwrap();
            let gpus: usize = cols[2].parse().unwrap();
            let bad: f64 = cols[3].trim_end_matches('%').parse().unwrap();
            rows.push((t, offered, gpus, bad / 100.0));
        }
    }
    assert!(rows.len() >= 10, "got {} epochs", rows.len());
    // GPU count varies (not pinned at the initial size).
    let min_g = rows.iter().map(|r| r.2).min().unwrap();
    let max_g = rows.iter().map(|r| r.2).max().unwrap();
    assert!(min_g < max_g, "autoscaler never changed the cluster");
    assert!(min_g < 64, "never consolidated below the initial 64");
    // Load-proportionality: correlation between offered load and GPUs.
    let n = rows.len() as f64;
    let mean_o = rows.iter().map(|r| r.1).sum::<f64>() / n;
    let mean_g = rows.iter().map(|r| r.2 as f64).sum::<f64>() / n;
    let cov: f64 = rows
        .iter()
        .map(|r| (r.1 - mean_o) * (r.2 as f64 - mean_g))
        .sum();
    assert!(cov > 0.0, "GPU count not positively tracking load");
    // Bad rate mostly low (bursts may transiently violate).
    let low_bad = rows.iter().filter(|r| r.3 < 0.05).count();
    assert!(
        low_bad as f64 >= 0.7 * n,
        "only {low_bad}/{} epochs with <5% bad",
        rows.len()
    );
}

/// Appendix A.2 at paper scale: 800 models, 20 partitions; the solver's
/// partition beats random search on the MILP objective and both
/// imbalance factors.
#[test]
fn partition_paper_scale() {
    let mut rng = Rng::new(4242);
    let p = partition::random_instance(800, 20, &mut rng);
    let budget = Duration::from_millis(400);
    let ours = partition::solve(&p, budget, &mut rng).expect("solver feasible");
    let rand = partition::random_search(&p, budget, &mut rng).expect("random feasible");
    assert!(p.feasible(&ours));
    let (ri, si) = p.imbalance(&ours);
    let (rr, sr) = p.imbalance(&rand);
    assert!(
        p.objective(&ours) < p.objective(&rand),
        "objective {} !< {}",
        p.objective(&ours),
        p.objective(&rand)
    );
    assert!(ri < rr, "rate imbalance {ri} !< {rr}");
    assert!(si < sr * 1.2, "mem imbalance {si} vs {sr}");
}

/// Disruption-bounded re-solve: with a tight switching budget the new
/// assignment stays close to the old one.
#[test]
fn partition_disruption_minimized() {
    let mut rng = Rng::new(99);
    let mut p = partition::random_instance(200, 8, &mut rng);
    let initial = partition::solve(&p, Duration::from_millis(150), &mut rng).unwrap();
    // Perturb rates, re-solve with a budget allowing ~10 moves.
    for m in p.models.iter_mut() {
        m.rate *= rng.range_f64(0.7, 1.4);
    }
    p.disruption = Some((initial.clone(), vec![1.0; 200], 20.0));
    let next = partition::solve(&p, Duration::from_millis(150), &mut rng).unwrap();
    let moves = initial.iter().zip(&next).filter(|(a, b)| a != b).count();
    assert!(moves <= 10, "moved {moves} models despite C_max");
    assert!(p.feasible(&next));
}
