//! Meta-tests for `symphony check`: the model checker must pass every
//! real fabric model, must *fail* both seeded-bug variants (a checker
//! that cannot re-find a bug we planted proves nothing), and must
//! explore deterministically (same model, same bound → same schedule
//! count — the property that makes `check --all` a reproducible CI
//! gate rather than a flaky sampler).
//!
//! `check_models_pass` is the tier-1 mirror of the CI
//! `symphony check --all` step, the same way `lint_tree_is_clean`
//! mirrors the `symphony lint` gate.

use symphony::check::{check_all, explore, find_model, ExploreConfig};

/// CI-grade bound: preemption 2, generous schedule cap. The cap must
/// never be the thing that ends exploration for the real models —
/// `exhausted` is asserted below so state-space growth shows up as a
/// test failure instead of silent under-coverage.
fn ci_config() -> ExploreConfig {
    ExploreConfig {
        preempt: 2,
        max_schedules: 50_000,
        random: None,
    }
}

/// Tier-1 mirror of the `symphony check --all` CI gate.
#[test]
fn check_models_pass() {
    let (reports, all_ok) = check_all(ci_config());
    let mut lines = String::new();
    for r in &reports {
        lines.push_str(&format!(
            "{:28} ok={} expect_fail={} schedules={} pruned={} exhausted={} failure={:?}\n",
            r.name,
            r.ok,
            r.expect_fail,
            r.report.schedules,
            r.report.pruned,
            r.report.exhausted,
            r.report.failure,
        ));
    }
    assert!(all_ok, "models missed their contracts:\n{lines}");
    for r in &reports {
        // A real model whose exploration was cut by the cap would be
        // vacuously "passing"; require the DFS to have finished (a
        // found failure also ends exploration legitimately).
        assert!(
            r.report.exhausted || r.report.failure.is_some(),
            "{}: exploration hit the schedule cap — raise it or shrink the model\n{lines}",
            r.name
        );
    }
}

/// The checker must re-find the Dekker-fence bug: `prepare` downgraded
/// to a fence-less Release store lets the producer miss PARKED while
/// the consumer misses the message (classic store-buffer litmus), and
/// the consumer then sleeps forever — a deadlock the explorer reports.
#[test]
fn seeded_parker_bug_is_caught() {
    let m = find_model("seeded-parker-nofence").expect("model registered");
    assert!(m.expect_fail);
    let report = explore(m.run, ci_config());
    let failure = report
        .failure
        .expect("seeded parker bug must produce a failing schedule");
    assert!(
        failure.contains("deadlock"),
        "expected a deadlock report, got: {failure}"
    );
}

/// The checker must re-find the downgraded-publish bug: a Relaxed
/// store of the slot sequence carries no happens-before edge, so the
/// consumer's payload read races the producer's write and the vector-
/// clock race detector objects.
#[test]
fn seeded_ring_bug_is_caught() {
    let m = find_model("seeded-ring-relaxed-publish").expect("model registered");
    assert!(m.expect_fail);
    let report = explore(m.run, ci_config());
    let failure = report
        .failure
        .expect("seeded ring bug must produce a failing schedule");
    assert!(
        failure.contains("race") || failure.contains("uninitialized"),
        "expected a data-race report, got: {failure}"
    );
}

/// Same model + same bound → bit-identical schedule counts. Object ids
/// are assigned at creation and every scheduling choice is replayed
/// from a recorded trace, so nothing about the host (thread timing,
/// hash seeds) may leak into the exploration shape.
#[test]
fn exploration_is_deterministic() {
    let m = find_model("parker-wake").expect("model registered");
    let a = explore(m.run, ci_config());
    let b = explore(m.run, ci_config());
    assert_eq!(a.schedules, b.schedules, "schedule count must be reproducible");
    assert_eq!(a.pruned, b.pruned, "prune count must be reproducible");
    assert!(a.exhausted && b.exhausted);
    assert!(a.failure.is_none() && b.failure.is_none());
}

/// Random-walk mode (`check --schedules N --seed S`): runs exactly N
/// schedules, never fails a correct model, and is reproducible per
/// seed — the same seed must reach the same verdict, so a nightly
/// sweep's failure can be replayed locally by quoting its seed.
#[test]
fn random_walk_mode_works() {
    let cfg = ExploreConfig {
        preempt: 2,
        max_schedules: 50_000,
        random: Some((64, 7)),
    };
    let m = find_model("parker-wake").expect("model registered");
    let r = explore(m.run, cfg);
    assert_eq!(r.schedules, 64);
    assert!(r.failure.is_none(), "real model failed under random walk: {:?}", r.failure);

    // Seed-reproducibility on a seeded-bug model: whatever verdict a
    // seed reaches, it reaches it again (the walk stops early on the
    // first failing schedule, so counts must agree too).
    let bug = find_model("seeded-ring-relaxed-publish").expect("model registered");
    let cfg = ExploreConfig {
        preempt: 2,
        max_schedules: 50_000,
        random: Some((32, 11)),
    };
    let a = explore(bug.run, cfg);
    let b = explore(bug.run, cfg);
    assert_eq!(a.schedules, b.schedules);
    assert_eq!(a.failure.is_some(), b.failure.is_some());
}
