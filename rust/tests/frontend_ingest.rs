//! Frontend ingest tier + ModelWorkerPool integration tests: the
//! sharded submit path must lose nothing, duplicate nothing, preserve
//! per-model deadline order, dispatch the same work as per-request
//! submission, amortize a k-request burst to one candidate recompute
//! per model, and keep the OS thread count at `W` regardless of the
//! model count.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver};
use std::time::{Duration, Instant};

use symphony::coordinator::{Completion, Coordinator, CoordinatorConfig, ToBackend};
use symphony::core::profile::LatencyProfile;
use symphony::core::time::Micros;
use symphony::core::types::{ModelId, Request, RequestId};

struct SinkCluster {
    coord: Coordinator,
    backend_rxs: Vec<Receiver<ToBackend>>,
    comp_rx: Receiver<Completion>,
}

fn spawn_cluster(
    n_models: usize,
    num_gpus: usize,
    initial_gpus: Option<usize>,
    rank_shards: usize,
    ingest_shards: usize,
    model_workers: Option<usize>,
    profile: LatencyProfile,
) -> SinkCluster {
    let mut backend_txs = Vec::new();
    let mut backend_rxs = Vec::new();
    for _ in 0..num_gpus {
        let (tx, rx) = channel::<ToBackend>();
        backend_txs.push(tx);
        backend_rxs.push(rx);
    }
    let (comp_tx, comp_rx) = channel::<Completion>();
    let coord = Coordinator::spawn(
        CoordinatorConfig {
            profiles: vec![profile; n_models],
            num_gpus,
            initial_gpus,
            rank_shards,
            ingest_shards,
            model_workers,
            net_bound: Micros::from_millis_f64(1.0),
            exec_margin: Micros::ZERO,
            remote_ranks: Vec::new(),
            busy_poll: false,
            pin_cores: false,
            reconnect: symphony::net::client::ReconnectPolicy::default(),
            fault_plan: symphony::net::faults::FaultPlan::none(),
        },
        backend_txs,
        comp_tx,
    );
    SinkCluster {
        coord,
        backend_rxs,
        comp_rx,
    }
}

/// Drain the sinks until `expected` requests are dispatched or dropped
/// (or timeout). Returns (dispatched batches, dropped requests).
fn collect_accounted(
    cluster: &SinkCluster,
    expected: usize,
    timeout: Duration,
) -> (Vec<(ModelId, Vec<Request>)>, Vec<Request>) {
    let mut batches: Vec<(ModelId, Vec<Request>)> = Vec::new();
    let mut dropped: Vec<Request> = Vec::new();
    let deadline = Instant::now() + timeout;
    let mut accounted = 0usize;
    while accounted < expected && Instant::now() < deadline {
        for rx in &cluster.backend_rxs {
            while let Ok(ToBackend::Execute { model, requests, .. }) = rx.try_recv() {
                accounted += requests.len();
                batches.push((model, requests.iter().copied().collect()));
            }
        }
        while let Ok(c) = cluster.comp_rx.try_recv() {
            if let Completion::Dropped(rs) = c {
                accounted += rs.len();
                dropped.extend(rs.iter().copied());
            }
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    (batches, dropped)
}

fn os_thread_count() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find(|l| l.starts_with("Threads:"))?
        .split_whitespace()
        .nth(1)?
        .parse()
        .ok()
}

/// Acceptance: 256 models on a 4-worker pool spawn 4 model threads,
/// not 256 — and the pool still serves models across the whole id
/// range.
#[test]
fn worker_pool_caps_os_threads_at_w() {
    let before = os_thread_count();
    let cluster = spawn_cluster(256, 8, None, 1, 2, Some(4), LatencyProfile::new(0.5, 2.0));
    assert_eq!(cluster.coord.num_model_workers(), 4);
    if let (Some(b), Some(a)) = (before, os_thread_count()) {
        // 4 workers + 1 rank shard + 2 ingest shards (+ slack for
        // concurrently running tests). The seed spawned one thread per
        // model: 256.
        let delta = a.saturating_sub(b);
        assert!(
            delta <= 64,
            "spawning a 256-model coordinator grew the process by {delta} \
             threads — the worker pool must cap this at W"
        );
    }
    // Liveness across the model id range (first/middle/last worker
    // slots).
    for (i, m) in [0u32, 127, 255].into_iter().enumerate() {
        cluster
            .coord
            .submit_now(i as u64, ModelId(m), Micros::from_millis_f64(120.0));
    }
    let (batches, dropped) = collect_accounted(&cluster, 3, Duration::from_secs(5));
    assert!(dropped.is_empty(), "nothing may drop: {dropped:?}");
    let models: std::collections::BTreeSet<u32> =
        batches.iter().map(|(m, _)| m.0).collect();
    assert_eq!(models, [0u32, 127, 255].into_iter().collect());
    let (front, _stats) = cluster.coord.shutdown_stats();
    assert_eq!(front.processed, 3);
    assert_eq!(front.dropped_submits, 0);
}

/// Acceptance: a k-request `submit_batch` burst costs exactly one
/// end-of-drain candidate recompute (and thus one shard registration)
/// per model. Zero attached GPUs keep grants/revalidations out of the
/// counter; far deadlines keep the candidates parked.
#[test]
fn burst_costs_one_flush_recompute_per_model() {
    let cluster = spawn_cluster(2, 1, Some(0), 1, 1, Some(1), LatencyProfile::new(0.5, 2.0));
    let now = cluster.coord.clock.now();
    let far = now + Micros::from_secs_f64(30.0);
    let mut batch: Vec<Request> = (0..24)
        .map(|i| Request {
            id: RequestId(i),
            model: ModelId((i % 2) as u32),
            arrival: now,
            deadline: far + Micros(i),
        })
        .collect();
    cluster.coord.submit_batch(&mut batch);
    // Let the worker drain + flush.
    std::thread::sleep(Duration::from_millis(100));
    let (front, stats) = cluster.coord.shutdown_stats();
    assert_eq!(front.processed, 24);
    assert_eq!(
        front.flush_recomputes, 2,
        "a 24-request burst over 2 models must recompute exactly twice"
    );
    assert_eq!(stats.grants, 0, "no GPU attached, no grant");
}

/// Multi-producer stress through `IngestHandle::submit_batch`: no
/// request lost, none duplicated, per-model deadline order preserved
/// within every dispatched batch.
#[test]
fn multi_producer_stress_no_loss_no_dup_ordered() {
    let n_models = 4usize;
    let producers = 6usize;
    let bursts_per_producer = 30usize;
    let cluster = spawn_cluster(n_models, 4, None, 2, 3, Some(2), LatencyProfile::new(0.05, 0.2));
    let clock = cluster.coord.clock;
    let slo = Micros::from_millis_f64(400.0);
    let mut feeders = Vec::new();
    for p in 0..producers as u64 {
        let handle = cluster.coord.ingest_handle();
        feeders.push(std::thread::spawn(move || {
            let mut sent = 0u64;
            let mut batch: Vec<Request> = Vec::new();
            for b in 0..bursts_per_producer as u64 {
                batch.clear();
                let size = 1 + ((p * 7 + b * 5) % 12);
                for k in 0..size {
                    let seq = b * 64 + k;
                    let now = clock.now();
                    batch.push(Request {
                        id: RequestId((p << 32) | seq),
                        model: ModelId(((p + k) % n_models as u64) as u32),
                        arrival: now,
                        // Distinct deadlines so the order assertion is
                        // meaningful.
                        deadline: now + slo + Micros(seq),
                    });
                    sent += 1;
                }
                handle.submit_batch(&batch);
                if b % 8 == 0 {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
            sent
        }));
    }
    let total: u64 = feeders.into_iter().map(|f| f.join().unwrap()).sum();
    let (batches, dropped) =
        collect_accounted(&cluster, total as usize, Duration::from_secs(15));

    // No loss, no duplication: the dispatched ∪ dropped multiset is
    // exactly the submitted set.
    let mut seen: HashMap<u64, usize> = HashMap::new();
    for (_, reqs) in &batches {
        for r in reqs {
            *seen.entry(r.id.0).or_default() += 1;
        }
    }
    for r in &dropped {
        *seen.entry(r.id.0).or_default() += 1;
    }
    assert_eq!(
        seen.len() as u64,
        total,
        "every submitted request must surface exactly once"
    );
    let dups: Vec<u64> = seen
        .iter()
        .filter(|(_, &c)| c != 1)
        .map(|(&id, _)| id)
        .collect();
    assert!(dups.is_empty(), "duplicated requests: {dups:?}");

    // Per-model deadline order inside every dispatched batch.
    for (m, reqs) in &batches {
        for w in reqs.windows(2) {
            assert!(
                w[0].deadline <= w[1].deadline,
                "model {m:?}: batch violates deadline order: {:?} > {:?}",
                w[0].deadline,
                w[1].deadline
            );
        }
    }
    let (front, _stats) = cluster.coord.shutdown_stats();
    assert_eq!(front.processed, total);
    assert_eq!(front.ingest_forwarded, total, "all traffic went through handles");
    assert_eq!(front.dropped_submits, 0);
}

/// Trace equivalence: on an identical workload, batched ingestion
/// dispatches the same request multiset as per-request submission
/// (here: everything, with zero scheduler drops on either path).
#[test]
fn batched_ingestion_matches_per_request_multiset() {
    let n = 480u64;
    let run = |batched: bool| -> Vec<u64> {
        let cluster = spawn_cluster(3, 4, None, 1, 2, Some(2), LatencyProfile::new(0.05, 0.2));
        let now = cluster.coord.clock.now();
        let slo = Micros::from_millis_f64(500.0);
        let mut reqs: Vec<Request> = (0..n)
            .map(|i| Request {
                id: RequestId(i),
                model: ModelId((i % 3) as u32),
                arrival: now,
                deadline: now + slo + Micros(i),
            })
            .collect();
        if batched {
            for chunk in reqs.chunks_mut(32) {
                cluster.coord.submit_batch(chunk);
            }
        } else {
            for &r in &reqs {
                cluster.coord.submit(r);
            }
        }
        let (batches, dropped) =
            collect_accounted(&cluster, n as usize, Duration::from_secs(15));
        assert!(
            dropped.is_empty(),
            "light load must not drop (batched={batched}): {} dropped",
            dropped.len()
        );
        let mut ids: Vec<u64> = batches
            .iter()
            .flat_map(|(_, reqs)| reqs.iter().map(|r| r.id.0))
            .collect();
        ids.sort_unstable();
        let (front, _stats) = cluster.coord.shutdown_stats();
        assert_eq!(front.processed, n);
        assert_eq!(front.dropped_submits, 0);
        ids
    };
    let per_request = run(false);
    let batched = run(true);
    assert_eq!(
        per_request, batched,
        "batched and per-request ingestion must dispatch the same multiset"
    );
    assert_eq!(per_request.len() as u64, n);
}

/// The queue-depth satellite's plumbing: with zero GPUs attached
/// nothing can dispatch, so after the workers flush, the probe must
/// read exactly the submitted backlog — the signal the autoscaler's
/// deep-backlog veto consumes (`WindowStats::queue_depth`).
#[test]
fn queue_depth_probe_reports_backlog() {
    let profile = LatencyProfile::new(0.2, 1.0);
    let cluster = spawn_cluster(2, 2, Some(0), 1, 1, Some(2), profile);
    let probe = cluster.coord.queue_depth_probe();
    assert_eq!(probe.total(), 0, "fresh pool has no backlog");
    let now = cluster.coord.clock.now();
    let slo = Micros::from_millis_f64(10_000.0); // nothing sheds in-test
    let n = 37u64;
    for i in 0..n {
        cluster.coord.submit(Request {
            id: RequestId(i),
            model: ModelId((i % 2) as u32),
            arrival: now,
            deadline: now + slo,
        });
    }
    // Wait for the workers' end-of-drain flush to publish.
    let deadline = Instant::now() + Duration::from_secs(5);
    while probe.total() != n && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(probe.total(), n, "backlog visible once flushed");
    let (front, stats) = cluster.coord.shutdown_stats();
    assert_eq!(front.processed, n);
    assert_eq!(stats.grants, 0, "no GPU attached, nothing granted");
}
