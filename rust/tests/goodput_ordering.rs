//! Cross-system goodput relationships the paper's evaluation claims —
//! the "shape" assertions of DESIGN.md §5: who wins, roughly by what
//! factor, and where the advantage disappears.

use symphony::core::model_zoo::{self, GpuKind};
use symphony::core::time::Micros;
use symphony::harness::{GoodputExperiment, SystemKind};

fn goodput(exp: &GoodputExperiment, sys: SystemKind) -> f64 {
    exp.goodput(|e| sys.build(&e.models, e.num_gpus, Micros::ZERO))
        .goodput
}

/// Table 2, ResNet50: Symphony > Shepherd > Nexus ≫ Clockwork, and
/// Symphony lands between the no-coordination and staggered analytical
/// throughputs, near the staggered one.
#[test]
fn table2_ordering_resnet50() {
    let exp = GoodputExperiment::new(vec![model_zoo::resnet50_table2()], 8).sim_secs(6.0);
    let sym = goodput(&exp, SystemKind::Symphony);
    let clk = goodput(&exp, SystemKind::Clockwork);
    let nex = goodput(&exp, SystemKind::Nexus { frontends: 1 });
    assert!(sym > nex, "symphony {sym} vs nexus {nex}");
    assert!(sym > clk, "symphony {sym} vs clockwork {clk}");
    // Paper: Symphony 5264 vs staggered analytical 5839 on this model.
    assert!((4600.0..5900.0).contains(&sym), "symphony {sym}");
    // Nexus near the no-coordination analytical 4501.
    assert!((3300.0..4800.0).contains(&nex), "nexus {nex}");
}

/// Fig 1: median batch sizes ordered Clockwork < Nexus < Symphony, with
/// Symphony at roughly twice Nexus (paper: 1, 6, 14).
#[test]
fn fig1_batch_ordering() {
    let exp = GoodputExperiment::new(vec![model_zoo::resnet50_table2()], 8).sim_secs(6.0);
    let median = |sys: SystemKind| {
        exp.goodput(|e| sys.build(&e.models, e.num_gpus, Micros::ZERO))
            .metrics
            .batch_hist_all()
            .median()
    };
    let clk = median(SystemKind::Clockwork);
    let nex = median(SystemKind::Nexus { frontends: 1 });
    let sym = median(SystemKind::Symphony);
    assert!(clk <= 3, "clockwork median {clk}");
    assert!(nex < sym, "nexus {nex} vs symphony {sym}");
    assert!(sym >= 12, "symphony median {sym} (paper: 14)");
}

/// Fig 7c: for a weak-batching model (BERT, β/α ≈ 0.02) deferred and
/// eager goodputs are essentially equal.
#[test]
fn weak_batching_no_advantage() {
    let bert = model_zoo::by_name(GpuKind::Gtx1080Ti, "BERT").unwrap();
    let models: Vec<_> = (0..4)
        .map(|i| {
            let mut m = bert.clone();
            m.name = format!("bert-{i}");
            m
        })
        .collect();
    let exp = GoodputExperiment::new(models, 8).sim_secs(4.0);
    let def = goodput(&exp, SystemKind::Symphony);
    let eag = goodput(&exp, SystemKind::Eager);
    let ratio = def / eag.max(1.0);
    assert!(
        (0.9..1.25).contains(&ratio),
        "BERT deferred/eager ratio {ratio}"
    );
}

/// Fig 11's headline: under tight SLOs and bursty multi-model load,
/// Symphony clearly beats the uncoordinated baseline (Nexus).
#[test]
fn tight_slo_bursty_advantage() {
    let models = model_zoo::resnet_like_variants(8, 25.0, GpuKind::Gtx1080Ti);
    let exp = GoodputExperiment::new(models, 16)
        .gamma_shape(0.05)
        .sim_secs(5.0);
    let sym = goodput(&exp, SystemKind::Symphony);
    let nex = goodput(&exp, SystemKind::Nexus { frontends: 1 });
    assert!(
        sym > nex * 1.2,
        "symphony {sym} should beat nexus {nex} by >20% here"
    );
}

/// Fig 2's flat-top property: Symphony's goodput under 2x overload
/// stays within 25% of its peak (Clockwork's collapses).
#[test]
fn flattop_under_overload() {
    let models = model_zoo::resnet_like_variants(10, 100.0, GpuKind::Gtx1080Ti);
    let exp = GoodputExperiment::new(models, 24).sim_secs(5.0);
    let peak = goodput(&exp, SystemKind::Symphony);
    let over = exp.run_at(peak * 2.0, &|e: &GoodputExperiment| {
        SystemKind::Symphony.build(&e.models, e.num_gpus, Micros::ZERO)
    });
    assert!(
        over.goodput() > peak * 0.75,
        "overloaded goodput {} vs peak {peak}",
        over.goodput()
    );
    // Clockwork under the same overload delivers less than Symphony
    // and (Fig 2 right) has burned all GPUs long before its peak.
    let clk_over = exp.run_at(peak * 2.0, &|e: &GoodputExperiment| {
        SystemKind::Clockwork.build(&e.models, e.num_gpus, Micros::ZERO)
    });
    assert!(
        clk_over.goodput() < over.goodput(),
        "clockwork overloaded {} vs symphony {}",
        clk_over.goodput(),
        over.goodput()
    );
    let clk_light = exp.run_at(3_000.0, &|e: &GoodputExperiment| {
        SystemKind::Clockwork.build(&e.models, e.num_gpus, Micros::ZERO)
    });
    assert!(
        clk_light.gpus_used() >= 20,
        "clockwork should occupy nearly all GPUs even at light load, used {}",
        clk_light.gpus_used()
    );
}

/// Fig 2 right: at one-third load, Symphony uses well under half the
/// GPUs while eager baselines occupy all of them.
#[test]
fn load_proportional_gpu_usage() {
    let models = model_zoo::resnet_like_variants(10, 100.0, GpuKind::Gtx1080Ti);
    let exp = GoodputExperiment::new(models, 24).sim_secs(5.0);
    let m_sym = exp.run_at(3_000.0, &|e: &GoodputExperiment| {
        SystemKind::Symphony.build(&e.models, e.num_gpus, Micros::ZERO)
    });
    let m_shep = exp.run_at(3_000.0, &|e: &GoodputExperiment| {
        SystemKind::Shepherd.build(&e.models, e.num_gpus, Micros::ZERO)
    });
    assert!(
        m_sym.gpus_used() <= 14,
        "symphony used {} GPUs at light load",
        m_sym.gpus_used()
    );
    assert!(
        m_shep.gpus_used() >= 20,
        "shepherd used only {} GPUs (expected all-busy eagerness)",
        m_shep.gpus_used()
    );
}
