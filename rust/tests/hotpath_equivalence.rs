//! Equivalence proofs for the integer-micros hot path (§Perf): the
//! closed-form integer `latency`/`max_batch_within` and the memoized
//! shedding target must agree with the seed float implementations
//! (kept verbatim in `core::profile::reference`) across random µs-grain
//! α/β/budget, the count-only planner must match the materializing
//! planner, and the refactored scheduler must produce byte-identical
//! dispatch traces run-to-run on random workloads.

use symphony::core::profile::{reference, LatencyProfile, ModelSpec};
use symphony::core::time::Micros;
use symphony::core::types::{ModelId, Request, RequestId};
use symphony::prop_assert;
use symphony::scheduler::batch_policy::ModelQueue;
use symphony::scheduler::deferred::DeferredScheduler;
use symphony::sim::{Engine, SimConfig, TraceEntry};
use symphony::util::proptest::{check, default_cases};
use symphony::util::rng::Rng;
use symphony::workload::WorkloadSpec;

/// Random profile with whole-µs α/β — the resolution of `Micros` and of
/// the paper's tables, and the domain on which integer and float math
/// are exactly equivalent.
fn us_grain_profile(rng: &mut Rng) -> (u64, u64, LatencyProfile) {
    let alpha_us = 1 + rng.below(20_000);
    let beta_us = rng.below(60_000);
    let p = LatencyProfile::new(alpha_us as f64 / 1_000.0, beta_us as f64 / 1_000.0);
    (alpha_us, beta_us, p)
}

/// Random budget, biased toward exact ℓ(b) boundaries where float
/// rounding is most fragile.
fn random_budget(rng: &mut Rng, alpha_us: u64, beta_us: u64) -> Micros {
    if rng.f64() < 0.25 {
        let b = rng.below(64);
        let jitter = rng.below(3); // boundary − 1, exact, + 1
        Micros((alpha_us * b + beta_us + jitter).saturating_sub(1))
    } else {
        Micros(rng.below(2_000_000))
    }
}

#[test]
fn prop_latency_integer_equals_float_reference() {
    check("latency_int_float", default_cases(), |rng| {
        let (alpha_us, beta_us, p) = us_grain_profile(rng);
        for _ in 0..64 {
            let b = 1 + rng.below(2_000) as u32;
            let int = p.latency(b);
            prop_assert!(
                int.0 == alpha_us * b as u64 + beta_us,
                "α={alpha_us} β={beta_us} b={b}: closed form {int:?}"
            );
            let flt = reference::latency(p.alpha_ms, p.beta_ms, b);
            prop_assert!(
                int == flt,
                "α={alpha_us} β={beta_us} b={b}: int {int:?} != float {flt:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_max_batch_within_integer_equals_float_reference() {
    check("max_batch_int_float", default_cases(), |rng| {
        let (alpha_us, beta_us, p) = us_grain_profile(rng);
        for _ in 0..64 {
            let budget = random_budget(rng, alpha_us, beta_us);
            let int = p.max_batch_within(budget);
            let flt = reference::max_batch_within(p.alpha_ms, p.beta_ms, budget);
            if int != flt {
                // Known one-ulp corner: the seed's early-out guard
                // compares ms floats, so exactly at the ℓ(1) boundary it
                // can report 0 where the integer math correctly fits 1.
                prop_assert!(
                    flt == 0 && int == 1 && p.latency(1) == budget,
                    "α={alpha_us} β={beta_us} budget={budget:?}: int {int} != float {flt}"
                );
            }
            // Self-consistency: the closed form is exactly the largest
            // fitting batch.
            if int > 0 {
                prop_assert!(
                    p.latency(int) <= budget && p.latency(int + 1) > budget,
                    "α={alpha_us} β={beta_us} budget={budget:?}: b={int} not maximal"
                );
            } else {
                prop_assert!(
                    p.latency(1) > budget,
                    "α={alpha_us} β={beta_us} budget={budget:?}: b=0 but ℓ(1) fits"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_target_batch_equals_float_reference() {
    check("target_batch_int_float", default_cases(), |rng| {
        let (alpha_us, beta_us, p) = us_grain_profile(rng);
        for _ in 0..32 {
            let slo = Micros(rng.below(4_000_000));
            let n = 1 + rng.below(64) as usize;
            let max_batch = if rng.f64() < 0.5 {
                0
            } else {
                1 + rng.below(64) as u32
            };
            let int = DeferredScheduler::target_batch(&p, slo, n, max_batch);
            let flt = reference::target_batch(p.alpha_ms, p.beta_ms, slo, n, max_batch);
            if int != flt {
                // Same documented ℓ(1)-boundary corner as above,
                // propagated through b*.
                prop_assert!(
                    flt == 0 && int == 1,
                    "α={alpha_us} β={beta_us} slo={slo:?} n={n} cap={max_batch}: \
                     int {int} != float {flt}"
                );
            }
        }
        Ok(())
    });
}

/// The count-only planner (`plan_len` + `take_list`, the dispatch hot
/// path) agrees exactly with the materializing planner (`plan_target`)
/// on random queues: same drops, same batch, same deadline, same
/// remaining queue.
#[test]
fn prop_plan_len_matches_plan_target() {
    check("plan_len_vs_plan_target", default_cases(), |rng| {
        let (_a, _b, p) = us_grain_profile(rng);
        let mut q = ModelQueue::new();
        let n = rng.below(40);
        let slo = 1_000 + rng.below(200_000);
        let mut arrival = 0u64;
        for i in 0..n {
            arrival += rng.below(3_000);
            // Occasional out-of-order deadline exercises the sorted
            // insert path too.
            let skew = rng.below(2_000);
            q.push(Request {
                id: RequestId(i),
                model: ModelId(0),
                arrival: Micros(arrival),
                deadline: Micros(arrival + slo + skew),
            });
        }
        let mut q2 = q.clone();
        let start = Micros(rng.below(300_000));
        let slack = Micros(rng.below(2_000));
        let max_batch = rng.below(20) as u32;
        let target = rng.below(20) as u32;
        let plan = q.plan_target(start, &p, slack, max_batch, target);
        let mut dropped = Vec::new();
        let (b, d) = q2.plan_len(start, &p, slack, max_batch, target, &mut dropped);
        prop_assert!(
            b == plan.batch.len(),
            "count {b} != materialized {}",
            plan.batch.len()
        );
        prop_assert!(d == plan.deadline, "deadline {d:?} != {:?}", plan.deadline);
        prop_assert!(
            dropped == plan.dropped,
            "drops {dropped:?} != {:?}",
            plan.dropped
        );
        let list = q2.take_list(b);
        prop_assert!(
            list.as_slice() == &plan.batch[..],
            "batch ids {list:?} != {:?}",
            plan.batch
        );
        prop_assert!(
            q2.len() + b == q.len(),
            "remaining {} + taken {b} != {}",
            q2.len(),
            q.len()
        );
        Ok(())
    });
}

fn trace_key(t: &TraceEntry) -> (u32, u32, u32, u64, u64, bool) {
    (t.gpu.0, t.model.0, t.size, t.start.0, t.end.0, t.preempted)
}

/// Byte-identical dispatch traces: the refactored planner (memoized
/// target, scratch buffers, timer dedup, heap compaction) is fully
/// deterministic — the same seed yields the exact same batch trace on
/// random workloads. (The fig4 worked example pins the absolute
/// numbers: `{R1..R4} @ t=2.25` in `scheduler::deferred::tests`.)
#[test]
fn prop_dispatch_trace_deterministic() {
    check("dispatch_trace_identical", 16, |rng| {
        let n_models = 1 + rng.below(4) as usize;
        let models: Vec<ModelSpec> = (0..n_models)
            .map(|i| {
                let alpha = (1 + rng.below(4_000)) as f64 / 1_000.0;
                let beta = rng.below(12_000) as f64 / 1_000.0;
                let min_slo = 2.0 * alpha + beta;
                ModelSpec::new(&format!("m{i}"), alpha, beta, min_slo * 2.5)
            })
            .collect();
        let gpus = 1 + rng.below(8) as usize;
        let rate = rng.range_f64(200.0, 4_000.0);
        let seed = rng.next_u64();
        let run = || {
            let spec = WorkloadSpec::new(models.clone(), rate).seed(seed);
            let sched = symphony::harness::SystemKind::Symphony.build(&models, gpus, Micros::ZERO);
            let cfg = SimConfig::new(gpus, Micros::from_secs_f64(1.5)).trace(true);
            Engine::new(spec.build(), sched, cfg)
                .run()
                .trace
                .iter()
                .map(trace_key)
                .collect::<Vec<_>>()
        };
        let t1 = run();
        let t2 = run();
        prop_assert!(!t1.is_empty(), "no batches dispatched at rate {rate}");
        prop_assert!(
            t1 == t2,
            "trace diverged: {} vs {} entries",
            t1.len(),
            t2.len()
        );
        Ok(())
    });
}
