//! Fixture self-tests for every `symphony lint` rule.
//!
//! Each rule gets three kinds of coverage:
//! - a **bad** snippet that must be flagged, with the expected line
//!   asserted (found by content, so fixtures can be edited without
//!   recounting lines);
//! - a **near-miss** that exercises the rule's documented exemptions
//!   and must stay silent;
//! - a **suppression round-trip**: a reasoned `lint:allow` silences
//!   the finding, a bare one does not — and is itself reported.
//!
//! The final test, `lint_tree_is_clean`, is the tier-1 guard: the
//! checked-in `rust/src` tree must lint clean, which is exactly what
//! the CI gate (`symphony lint`) enforces.

use symphony::lint::{lint_sources, Finding};

/// 1-based line of the first fixture line containing `needle`.
fn line_of(src: &str, needle: &str) -> usize {
    src.lines()
        .position(|l| l.contains(needle))
        .unwrap_or_else(|| panic!("fixture needle not found: {needle}"))
        + 1
}

fn only(path: &str, src: &str, rule: &str) -> Vec<Finding> {
    lint_sources(&[(path, src)], Some(rule))
}

fn assert_flagged(findings: &[Finding], rule: &str, line: usize) {
    assert!(
        findings.iter().any(|f| f.rule == rule && f.line == line),
        "expected a `{rule}` finding on line {line}, got:\n{}",
        render(findings)
    );
}

fn render(findings: &[Finding]) -> String {
    findings
        .iter()
        .map(|f| f.to_string())
        .collect::<Vec<_>>()
        .join("\n")
}

// ---------------------------------------------------------------- micros

const MICROS_RULE: &str = "unchecked-micros-arith";

const MICROS_BAD: &str = r#"
use crate::core::time::Micros;

pub fn slack(deadline: Micros, now: Micros) -> Micros {
    deadline - now
}

pub fn advance(busy_until: &mut Micros, exec: Micros) {
    *busy_until += exec;
}
"#;

#[test]
fn micros_arith_flags_bare_ops() {
    let f = only("coordinator/hotpath.rs", MICROS_BAD, MICROS_RULE);
    assert_eq!(f.len(), 2, "findings:\n{}", render(&f));
    assert_flagged(&f, MICROS_RULE, line_of(MICROS_BAD, "deadline - now"));
    assert_flagged(&f, MICROS_RULE, line_of(MICROS_BAD, "busy_until += exec"));
    assert!(f[0].message.contains("saturating_sub"), "{}", f[0]);
    assert!(f[1].message.contains("saturating_add"), "{}", f[1]);
}

#[test]
fn micros_arith_is_scoped_to_serving_path_files() {
    // Same source under a sim/harness path: outside the target list.
    let f = only("sim/workload.rs", MICROS_BAD, MICROS_RULE);
    assert!(f.is_empty(), "findings:\n{}", render(&f));
}

const MICROS_NEAR: &str = r#"
use std::time::{Duration, Instant};

pub struct Window { pub last: Micros }

pub fn wake(timeout: Duration) -> Instant {
    Instant::now() + timeout
}

pub fn seen_since(w: &Window, good: u64) -> u64 {
    good - w.last.0
}

pub fn width(total: u64, done: u64) -> u64 {
    total - done
}
"#;

#[test]
fn micros_arith_ignores_std_time_and_tuple_payloads() {
    // `Instant::now() + timeout` is std-time arithmetic; `w.last.0` is
    // the u64 *inside* a Micros field, not a Micros; `total - done`
    // involves no time names at all.
    let f = only("coordinator/hotpath.rs", MICROS_NEAR, MICROS_RULE);
    assert!(f.is_empty(), "findings:\n{}", render(&f));
}

const MICROS_ALLOW_OK: &str = r#"
use crate::core::time::Micros;

pub fn lag(now: Micros, arrival: Micros) -> Micros {
    // lint:allow(unchecked-micros-arith): fixture: the caller pins arrival <= now
    now - arrival
}
"#;

const MICROS_ALLOW_BARE: &str = r#"
use crate::core::time::Micros;

pub fn lag(now: Micros, arrival: Micros) -> Micros {
    // lint:allow(unchecked-micros-arith)
    now - arrival
}
"#;

#[test]
fn micros_arith_suppression_round_trip() {
    let ok = lint_sources(&[("coordinator/hotpath.rs", MICROS_ALLOW_OK)], None);
    assert!(ok.is_empty(), "findings:\n{}", render(&ok));

    let bare = lint_sources(&[("coordinator/hotpath.rs", MICROS_ALLOW_BARE)], None);
    assert_eq!(bare.len(), 2, "findings:\n{}", render(&bare));
    assert_flagged(&bare, "suppression", line_of(MICROS_ALLOW_BARE, "lint:allow"));
    assert_flagged(&bare, MICROS_RULE, line_of(MICROS_ALLOW_BARE, "now - arrival"));
}

// ----------------------------------------------------------------- float

const FLOAT_RULE: &str = "float-free-hot-path";

const FLOAT_BAD: &str = r#"
pub fn target_batch(slo_us: u64) -> u64 {
    let goal = 0.9 * slo_us as f64;
    goal as u64
}
"#;

#[test]
fn float_free_flags_integer_signature_fn() {
    let f = only("scheduler/deferred.rs", FLOAT_BAD, FLOAT_RULE);
    // Both the `0.9` literal and the `f64` cast token are findings.
    assert_eq!(f.len(), 2, "findings:\n{}", render(&f));
    let line = line_of(FLOAT_BAD, "let goal");
    assert!(f.iter().all(|x| x.rule == FLOAT_RULE && x.line == line));
    assert!(f[0].message.contains("target_batch"), "{}", f[0]);
}

const FLOAT_NEAR: &str = r#"
pub const ALPHA: f64 = 0.2;

pub fn throughput(batch: u64, window_s: f64) -> f64 {
    batch as f64 / window_s
}

#[cfg(test)]
mod tests {
    #[test]
    fn floats_fine_here() {
        let x = 1.5_f64;
        assert!(x > 1.0);
    }
}
"#;

#[test]
fn float_free_ignores_float_signatures_items_and_tests() {
    let f = only("scheduler/deferred.rs", FLOAT_NEAR, FLOAT_RULE);
    assert!(f.is_empty(), "findings:\n{}", render(&f));
}

const FLOAT_ALLOW_OK: &str = r#"
pub fn target_batch(slo_us: u64) -> u64 {
    // lint:allow(float-free-hot-path): fixture: memoized cold path pinned by property tests
    let goal = 0.9 * slo_us as f64;
    goal as u64
}
"#;

const FLOAT_ALLOW_BARE: &str = r#"
pub fn target_batch(slo_us: u64) -> u64 {
    // lint:allow(float-free-hot-path)
    let goal = 0.9 * slo_us as f64;
    goal as u64
}
"#;

#[test]
fn float_free_suppression_round_trip() {
    let ok = lint_sources(&[("scheduler/deferred.rs", FLOAT_ALLOW_OK)], None);
    assert!(ok.is_empty(), "findings:\n{}", render(&ok));

    let bare = lint_sources(&[("scheduler/deferred.rs", FLOAT_ALLOW_BARE)], None);
    // One suppression finding; the two float findings survive unsuppressed.
    assert_eq!(bare.len(), 3, "findings:\n{}", render(&bare));
    assert_flagged(&bare, "suppression", line_of(FLOAT_ALLOW_BARE, "lint:allow"));
    assert_flagged(&bare, FLOAT_RULE, line_of(FLOAT_ALLOW_BARE, "let goal"));
}

// ----------------------------------------------------------------- drift

const DRIFT_RULE: &str = "wire-schema-drift";

const DRIFT_MESSAGES: &str = r#"
use std::sync::mpsc::Sender;

pub enum ToModel {
    Request(Request),
    Requests { model: ModelId },
    Granted { model: ModelId, gpu: GpuId },
    Revalidate { model: ModelId },
    Shutdown,
}

pub enum ToRank {
    Candidate { model: ModelId, seq: u64 },
    Drain { gpu: GpuId, ack: Sender<GpuId> },
    Shutdown,
}
"#;

const DRIFT_CODEC_OK: &str = r#"
pub enum WireToRank {
    Candidate { model: ModelId, seq: u64 },
    Drain { gpu: GpuId },
}

pub enum WireFromRank {
    Granted { model: ModelId, gpu: GpuId },
    Revalidate { model: ModelId },
    DrainAck { gpu: GpuId },
}

pub fn encode_up(m: &WireToRank, out: &mut Vec<u8>) {
    match m {
        WireToRank::Candidate { .. } => out.push(1),
        WireToRank::Drain { .. } => out.push(2),
    }
}

pub fn decode_up(tag: u8) -> Option<WireToRank> {
    match tag {
        1 => Some(WireToRank::Candidate { model: 0, seq: 0 }),
        2 => Some(WireToRank::Drain { gpu: 0 }),
        _ => None,
    }
}

pub fn encode_down(m: &WireFromRank, out: &mut Vec<u8>) {
    match m {
        WireFromRank::Granted { .. } => out.push(1),
        WireFromRank::Revalidate { .. } => out.push(2),
        WireFromRank::DrainAck { .. } => out.push(3),
    }
}

pub fn decode_down(tag: u8) -> Option<WireFromRank> {
    match tag {
        1 => Some(WireFromRank::Granted { model: 0, gpu: 0 }),
        2 => Some(WireFromRank::Revalidate { model: 0 }),
        3 => Some(WireFromRank::DrainAck { gpu: 0 }),
        _ => None,
    }
}

pub struct ServerPreamble {
    pub shards: u16,
    pub session: u64,
}

pub fn encode_preamble(p: &ServerPreamble, out: &mut Vec<u8>) {
    out.extend(p.shards.to_le_bytes());
    out.extend(p.session.to_le_bytes());
}

pub fn decode_preamble() -> ServerPreamble {
    ServerPreamble { shards: 0, session: 1 }
}

pub struct ClientHello {
    pub n_models: u32,
    pub epoch: u64,
}

pub fn encode_hello(h: &ClientHello, out: &mut Vec<u8>) {
    out.extend(h.n_models.to_le_bytes());
    out.extend(h.epoch.to_le_bytes());
}

pub fn decode_hello() -> ClientHello {
    ClientHello { n_models: 0, epoch: 0 }
}
"#;

fn drift(codec: &str) -> Vec<Finding> {
    lint_sources(
        &[
            ("coordinator/messages.rs", DRIFT_MESSAGES),
            ("net/codec.rs", codec),
        ],
        Some(DRIFT_RULE),
    )
}

#[test]
fn wire_drift_clean_pair_is_silent() {
    let f = drift(DRIFT_CODEC_OK);
    assert!(f.is_empty(), "findings:\n{}", render(&f));
}

#[test]
fn wire_drift_flags_missing_wire_variant() {
    let bad = DRIFT_CODEC_OK.replace("    Drain { gpu: GpuId },\n", "");
    assert_ne!(bad, DRIFT_CODEC_OK);
    let f = drift(&bad);
    assert_eq!(f.len(), 1, "findings:\n{}", render(&f));
    assert_flagged(&f, DRIFT_RULE, line_of(&bad, "pub enum WireToRank"));
    assert!(f[0].message.contains("missing `Drain`"), "{}", f[0]);
}

#[test]
fn wire_drift_flags_missing_decode_arm() {
    let bad = DRIFT_CODEC_OK
        .replace("        2 => Some(WireFromRank::Revalidate { model: 0 }),\n", "");
    assert_ne!(bad, DRIFT_CODEC_OK);
    let f = drift(&bad);
    assert_eq!(f.len(), 1, "findings:\n{}", render(&f));
    assert_flagged(&f, DRIFT_RULE, line_of(&bad, "pub fn decode_down"));
    assert!(
        f[0].message.contains("decode_down") && f[0].message.contains("Revalidate"),
        "{}",
        f[0]
    );
}

#[test]
fn wire_drift_flags_field_drift() {
    let bad = DRIFT_CODEC_OK.replace(
        "Candidate { model: ModelId, seq: u64 },",
        "Candidate { model: ModelId, sequence: u64 },",
    );
    assert_ne!(bad, DRIFT_CODEC_OK);
    let f = drift(&bad);
    assert_eq!(f.len(), 1, "findings:\n{}", render(&f));
    assert_flagged(&f, DRIFT_RULE, line_of(&bad, "pub enum WireToRank"));
    assert!(f[0].message.contains("drift from"), "{}", f[0]);
}

/// Handshake structs are fixed-offset (no per-field tags): a field the
/// encoder writes but the decoder never reads must be flagged, because
/// at runtime it silently skews every later offset instead of failing.
#[test]
fn wire_drift_flags_one_sided_handshake_field() {
    let bad = DRIFT_CODEC_OK.replace(
        "ClientHello { n_models: 0, epoch: 0 }",
        "ClientHello { n_models: 0, ..Default::default() }",
    );
    assert_ne!(bad, DRIFT_CODEC_OK);
    let f = drift(&bad);
    assert_eq!(f.len(), 1, "findings:\n{}", render(&f));
    assert_flagged(&f, DRIFT_RULE, line_of(&bad, "pub struct ClientHello"));
    assert!(
        f[0].message.contains("decode_hello") && f[0].message.contains("ClientHello::epoch"),
        "{}",
        f[0]
    );
}

#[test]
fn wire_drift_suppression_round_trip() {
    let missing = DRIFT_CODEC_OK.replace("    Drain { gpu: GpuId },\n", "");
    let ok = missing.replace(
        "pub enum WireToRank {",
        "// lint:allow(wire-schema-drift): fixture: variant staged for the next frame-format bump\n\
         pub enum WireToRank {",
    );
    let f = drift(&ok);
    assert!(f.is_empty(), "findings:\n{}", render(&f));

    let bare = missing.replace(
        "pub enum WireToRank {",
        "// lint:allow(wire-schema-drift)\npub enum WireToRank {",
    );
    let f = lint_sources(
        &[
            ("coordinator/messages.rs", DRIFT_MESSAGES),
            ("net/codec.rs", bare.as_str()),
        ],
        None,
    );
    assert_eq!(f.len(), 2, "findings:\n{}", render(&f));
    assert_flagged(&f, "suppression", line_of(&bare, "lint:allow"));
    assert_flagged(&f, DRIFT_RULE, line_of(&bare, "pub enum WireToRank"));
}

// ----------------------------------------------------------------- panic

const PANIC_RULE: &str = "panic-free-wire-surface";

const PANIC_BAD: &str = r#"
pub fn parse(frame: &[u8]) -> u32 {
    let tag = frame[0];
    let len = frame.last().unwrap();
    u32::from(tag) * u32::from(*len)
}
"#;

#[test]
fn panic_free_flags_unwrap_and_index() {
    let f = only("net/server.rs", PANIC_BAD, PANIC_RULE);
    assert_eq!(f.len(), 2, "findings:\n{}", render(&f));
    assert_flagged(&f, PANIC_RULE, line_of(PANIC_BAD, "frame[0]"));
    assert_flagged(&f, PANIC_RULE, line_of(PANIC_BAD, ".unwrap()"));
}

const PANIC_NEAR_SERVER: &str = r#"
pub fn read_tag(buf: &[u8]) -> Option<u8> {
    debug_assert!(!buf.is_empty());
    let _scratch = [0u8; 4];
    buf.get(0).copied()
}
"#;

const PANIC_NEAR_CODEC: &str = r#"
pub fn encode_hello(out: &mut Vec<u8>) {
    out[0] = 7;
}
"#;

#[test]
fn panic_free_ignores_debug_assert_arrays_and_encode_half() {
    // debug_assert! compiles out of release; `[0u8; 4]` is an array
    // literal, not an index; `.get()` is the sanctioned access; and the
    // encode half of codec.rs takes process-local input.
    let f = lint_sources(
        &[
            ("net/server.rs", PANIC_NEAR_SERVER),
            ("net/codec.rs", PANIC_NEAR_CODEC),
        ],
        Some(PANIC_RULE),
    );
    assert!(f.is_empty(), "findings:\n{}", render(&f));
}

const PANIC_ALLOW_OK: &str = r#"
pub fn read_tag(buf: &[u8]) -> u8 {
    buf[0] // lint:allow(panic-free-wire-surface): fixture: caller verified len >= 1
}
"#;

const PANIC_ALLOW_BARE: &str = r#"
pub fn read_tag(buf: &[u8]) -> u8 {
    buf[0] // lint:allow(panic-free-wire-surface)
}
"#;

#[test]
fn panic_free_suppression_round_trip() {
    // Trailing form: the allow shares the offending line.
    let ok = lint_sources(&[("net/server.rs", PANIC_ALLOW_OK)], None);
    assert!(ok.is_empty(), "findings:\n{}", render(&ok));

    let bare = lint_sources(&[("net/server.rs", PANIC_ALLOW_BARE)], None);
    assert_eq!(bare.len(), 2, "findings:\n{}", render(&bare));
    let line = line_of(PANIC_ALLOW_BARE, "buf[0]");
    assert_flagged(&bare, "suppression", line);
    assert_flagged(&bare, PANIC_RULE, line);
}

// ------------------------------------------------------------------ lock

const LOCK_RULE: &str = "lock-across-send";

const LOCK_BAD: &str = r#"
use std::sync::{mpsc::Receiver, Mutex};
use std::thread::JoinHandle;

pub struct Pool {
    handle: Mutex<Option<JoinHandle<()>>>,
    depth: Mutex<u64>,
}

impl Pool {
    pub fn shutdown(&self) {
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }

    pub fn drain(&self, rx: &Receiver<u64>) -> u64 {
        let g = self.depth.lock().unwrap();
        let seed = *g;
        rx.recv().unwrap_or(seed)
    }

    pub fn publish(&self, tx: &std::sync::mpsc::Sender<u64>) {
        let g = relock(&self.depth);
        let v = *g;
        let _ = tx.send(v);
    }
}
"#;

#[test]
fn lock_send_flags_scrutinee_binding_and_relock_guards() {
    let f = only("coordinator/pool.rs", LOCK_BAD, LOCK_RULE);
    assert_eq!(f.len(), 3, "findings:\n{}", render(&f));
    // Edition-2021 scrutinee temporary: the guard lives through the
    // whole `if let` body, so the join runs with the mutex held.
    assert_flagged(&f, LOCK_RULE, line_of(LOCK_BAD, "self.handle.lock()"));
    // Named guard binding still in scope across `.recv()`.
    assert_flagged(&f, LOCK_RULE, line_of(LOCK_BAD, "self.depth.lock()"));
    // The relock() helper produces a guard too.
    assert_flagged(&f, LOCK_RULE, line_of(LOCK_BAD, "relock(&self.depth)"));
}

const LOCK_NEAR: &str = r#"
impl Pool {
    pub fn shutdown_hoisted(&self) {
        let joiner = self.handle.lock().unwrap().take();
        if let Some(h) = joiner {
            let _ = h.join();
        }
    }

    pub fn publish(&self, tx: &std::sync::mpsc::Sender<u64>) {
        let g = self.depth.lock().unwrap();
        let v = *g;
        drop(g);
        let _ = tx.send(v);
    }

    pub fn peek(&self) -> u64 {
        let Ok(g) = self.depth.lock() else { return 0 };
        *g
    }
}
"#;

#[test]
fn lock_send_ignores_hoisted_dropped_and_let_else_guards() {
    // Hoisting `.take()` into its own statement, `drop(g)` before the
    // send, and `let .. else` (whose scrutinee temporaries drop at the
    // statement end) are all the sanctioned shapes.
    let f = only("coordinator/pool.rs", LOCK_NEAR, LOCK_RULE);
    assert!(f.is_empty(), "findings:\n{}", render(&f));
}

const LOCK_ALLOW_OK: &str = r#"
impl Pool {
    pub fn shutdown(&self) {
        // lint:allow(lock-across-send): fixture: the joined thread never takes this mutex
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}
"#;

const LOCK_ALLOW_BARE: &str = r#"
impl Pool {
    pub fn shutdown(&self) {
        // lint:allow(lock-across-send)
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}
"#;

#[test]
fn lock_send_suppression_round_trip() {
    let ok = lint_sources(&[("coordinator/pool.rs", LOCK_ALLOW_OK)], None);
    assert!(ok.is_empty(), "findings:\n{}", render(&ok));

    let bare = lint_sources(&[("coordinator/pool.rs", LOCK_ALLOW_BARE)], None);
    assert_eq!(bare.len(), 2, "findings:\n{}", render(&bare));
    assert_flagged(&bare, "suppression", line_of(LOCK_ALLOW_BARE, "lint:allow"));
    assert_flagged(&bare, LOCK_RULE, line_of(LOCK_ALLOW_BARE, "self.handle.lock()"));
}

// --------------------------------------------------------------- channel

const CHANNEL_RULE: &str = "hot-path-channel";

const CHANNEL_BAD: &str = r#"
use std::sync::mpsc::{channel, sync_channel};

pub fn spawn_inbox() {
    let (tx, rx) = channel::<u64>();
    let (btx, brx) = sync_channel(8);
    let _ = (tx, rx, btx, brx);
}
"#;

#[test]
fn hot_path_channel_flags_construction_in_coordinator() {
    let f = only("coordinator/inbox.rs", CHANNEL_BAD, CHANNEL_RULE);
    assert_eq!(f.len(), 2, "findings:\n{}", render(&f));
    assert_flagged(&f, CHANNEL_RULE, line_of(CHANNEL_BAD, "channel::<u64>()"));
    assert_flagged(&f, CHANNEL_RULE, line_of(CHANNEL_BAD, "sync_channel(8)"));
    assert!(f[0].message.contains("util::ring"), "{}", f[0]);
}

#[test]
fn hot_path_channel_is_scoped_to_coordinator() {
    // The same construction outside coordinator/ is not this rule's
    // business (serve/, net/, and the benches keep their mpsc edges).
    let f = only("net/client.rs", CHANNEL_BAD, CHANNEL_RULE);
    assert!(f.is_empty(), "findings:\n{}", render(&f));
}

const CHANNEL_NEAR: &str = r#"
pub fn wire(conn: &Conn) -> u32 {
    let c = conn.channel();
    c.id()
}

#[cfg(test)]
mod tests {
    use std::sync::mpsc::channel;

    #[test]
    fn harness_channels_are_fine() {
        let (tx, rx) = channel::<u8>();
        let _ = (tx, rx);
    }
}
"#;

#[test]
fn hot_path_channel_ignores_methods_imports_and_tests() {
    // `.channel()` is a method of the same name, the `use` line is an
    // import not a construction, and #[cfg(test)] code is exempt.
    let f = only("coordinator/inbox.rs", CHANNEL_NEAR, CHANNEL_RULE);
    assert!(f.is_empty(), "findings:\n{}", render(&f));
}

const CHANNEL_ALLOW_OK: &str = r#"
pub fn drain_ack() {
    // lint:allow(hot-path-channel): fixture: one-shot control-rate ack, not a hot hop
    let (tx, rx) = std::sync::mpsc::channel::<u32>();
    let _ = (tx, rx);
}
"#;

const CHANNEL_ALLOW_BARE: &str = r#"
pub fn drain_ack() {
    // lint:allow(hot-path-channel)
    let (tx, rx) = std::sync::mpsc::channel::<u32>();
    let _ = (tx, rx);
}
"#;

#[test]
fn hot_path_channel_suppression_round_trip() {
    let ok = lint_sources(&[("coordinator/inbox.rs", CHANNEL_ALLOW_OK)], None);
    assert!(ok.is_empty(), "findings:\n{}", render(&ok));

    let bare = lint_sources(&[("coordinator/inbox.rs", CHANNEL_ALLOW_BARE)], None);
    assert_eq!(bare.len(), 2, "findings:\n{}", render(&bare));
    assert_flagged(&bare, "suppression", line_of(CHANNEL_ALLOW_BARE, "lint:allow"));
    assert_flagged(
        &bare,
        CHANNEL_RULE,
        line_of(CHANNEL_ALLOW_BARE, "mpsc::channel::<u32>()"),
    );
}

// ---------------------------------------------------------------- unsafe

const UNSAFE_RULE: &str = "unsafe-needs-safety";

const UNSAFE_BAD: &str = r#"
pub fn poke(p: *mut u64) {
    unsafe { *p = 1 };
}

// updates the counter in place (a what-comment, not a safety argument)
pub unsafe fn bump(p: *mut u64) {
    *p += 1;
}
"#;

#[test]
fn unsafe_safety_flags_missing_and_non_safety_comments() {
    let f = only("util/slots.rs", UNSAFE_BAD, UNSAFE_RULE);
    assert_eq!(f.len(), 2, "findings:\n{}", render(&f));
    assert_flagged(&f, UNSAFE_RULE, line_of(UNSAFE_BAD, "unsafe { *p = 1 }"));
    // A comment above that never says SAFETY: does not justify.
    assert_flagged(&f, UNSAFE_RULE, line_of(UNSAFE_BAD, "pub unsafe fn bump"));
    assert!(f[0].message.contains("SAFETY:"), "{}", f[0]);
}

const UNSAFE_NEAR: &str = r#"
pub struct SharedSlots(*mut u64);

// SAFETY: slots are owned per-index; two threads never alias an index.
unsafe impl Send for SharedSlots {}
unsafe impl Sync for SharedSlots {}

pub fn read_above(s: &SharedSlots) -> u64 {
    // SAFETY: index 0 is always initialized by the constructor.
    unsafe { *s.0 }
}

pub fn read_trailing(s: &SharedSlots) -> u64 {
    unsafe { *s.0 } // SAFETY: same invariant as read_above.
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_are_exempt() {
        let mut x = 0u64;
        unsafe { *(&mut x as *mut u64) = 7 };
        assert_eq!(x, 7);
    }
}
"#;

#[test]
fn unsafe_safety_accepts_above_trailing_shared_and_test_forms() {
    // One SAFETY comment may cover a Send/Sync impl pair (the walk
    // skips upward over sibling `unsafe` lines); trailing same-line
    // comments count; #[cfg(test)] modules are exempt.
    let f = only("util/slots.rs", UNSAFE_NEAR, UNSAFE_RULE);
    assert!(f.is_empty(), "findings:\n{}", render(&f));
}

const UNSAFE_ALLOW_OK: &str = r#"
pub fn poke(p: *mut u64) {
    // lint:allow(unsafe-needs-safety): fixture: invariant documented on the one caller
    unsafe { *p = 1 };
}
"#;

const UNSAFE_ALLOW_BARE: &str = r#"
pub fn poke(p: *mut u64) {
    // lint:allow(unsafe-needs-safety)
    unsafe { *p = 1 };
}
"#;

#[test]
fn unsafe_safety_suppression_round_trip() {
    let ok = lint_sources(&[("util/slots.rs", UNSAFE_ALLOW_OK)], None);
    assert!(ok.is_empty(), "findings:\n{}", render(&ok));

    let bare = lint_sources(&[("util/slots.rs", UNSAFE_ALLOW_BARE)], None);
    assert_eq!(bare.len(), 2, "findings:\n{}", render(&bare));
    assert_flagged(&bare, "suppression", line_of(UNSAFE_ALLOW_BARE, "lint:allow"));
    assert_flagged(&bare, UNSAFE_RULE, line_of(UNSAFE_ALLOW_BARE, "unsafe { *p = 1 }"));
}

// --------------------------------------------------------------- relaxed

const RELAXED_RULE: &str = "relaxed-ordering-reason";

const RELAXED_BAD: &str = r#"
use std::sync::atomic::{AtomicUsize, Ordering};

pub fn publish(seq: &AtomicUsize) {
    seq.store(1, Ordering::Relaxed);
}

pub fn claim(count: &AtomicUsize) -> bool {
    count
        .fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |c| c.checked_sub(1),
        )
        .is_ok()
}
"#;

#[test]
fn relaxed_reason_flags_bare_uses_on_fabric_files() {
    let f = only("util/ring.rs", RELAXED_BAD, RELAXED_RULE);
    // The store, plus each continuation line of the fetch_update.
    assert_eq!(f.len(), 3, "findings:\n{}", render(&f));
    assert_flagged(&f, RELAXED_RULE, line_of(RELAXED_BAD, "seq.store"));
    assert!(f[0].message.contains("relaxed:"), "{}", f[0]);
}

#[test]
fn relaxed_reason_is_scoped_to_fabric_files() {
    // Plain statistics counters outside the fabric (ingest drop counts
    // and friends) are not protocol edges.
    let f = only("coordinator/ingest.rs", RELAXED_BAD, RELAXED_RULE);
    assert!(f.is_empty(), "findings:\n{}", render(&f));
}

const RELAXED_NEAR: &str = r#"
use std::sync::atomic::{AtomicUsize, Ordering};

pub fn publish(seq: &AtomicUsize) {
    seq.store(1, Ordering::Relaxed); // relaxed: advisory counter, no payload rides this edge
}

pub fn claim(count: &AtomicUsize) -> bool {
    // relaxed: the CAS loop's atomicity is the whole claim; nothing
    // else is published through this counter.
    count
        .fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |c| c.checked_sub(1),
        )
        .is_ok()
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn tests_are_exempt() {
        let n = AtomicUsize::new(0);
        n.store(1, Ordering::Relaxed);
        assert_eq!(n.load(Ordering::Relaxed), 1);
    }
}
"#;

#[test]
fn relaxed_reason_accepts_trailing_statement_comment_and_tests() {
    // A trailing `relaxed:` comment, a comment run above a multi-line
    // statement (covering Relaxed tokens on its continuation lines),
    // and #[cfg(test)] code are all fine.
    let f = only("util/ring.rs", RELAXED_NEAR, RELAXED_RULE);
    assert!(f.is_empty(), "findings:\n{}", render(&f));
}

const RELAXED_ALLOW_OK: &str = r#"
use std::sync::atomic::{AtomicUsize, Ordering};

pub fn publish(seq: &AtomicUsize) {
    // lint:allow(relaxed-ordering-reason): fixture: counter is advisory in this model
    seq.store(1, Ordering::Relaxed);
}
"#;

const RELAXED_ALLOW_BARE: &str = r#"
use std::sync::atomic::{AtomicUsize, Ordering};

pub fn publish(seq: &AtomicUsize) {
    // lint:allow(relaxed-ordering-reason)
    seq.store(1, Ordering::Relaxed);
}
"#;

#[test]
fn relaxed_reason_suppression_round_trip() {
    let ok = lint_sources(&[("util/ring.rs", RELAXED_ALLOW_OK)], None);
    assert!(ok.is_empty(), "findings:\n{}", render(&ok));

    let bare = lint_sources(&[("util/ring.rs", RELAXED_ALLOW_BARE)], None);
    assert_eq!(bare.len(), 2, "findings:\n{}", render(&bare));
    assert_flagged(&bare, "suppression", line_of(RELAXED_ALLOW_BARE, "lint:allow"));
    assert_flagged(&bare, RELAXED_RULE, line_of(RELAXED_ALLOW_BARE, "seq.store"));
}

// -------------------------------------------------------------- eprintln

const EPRINTLN_RULE: &str = "no-bare-eprintln";

const EPRINTLN_BAD: &str = r#"
pub fn dial(addr: &str) {
    eprintln!("client: dialing {addr}");
    println!("client: connected to {addr}");
}
"#;

#[test]
fn no_bare_eprintln_flags_prints_in_scope() {
    for path in ["net/client.rs", "coordinator/ingest.rs"] {
        let f = only(path, EPRINTLN_BAD, EPRINTLN_RULE);
        assert_eq!(f.len(), 2, "findings for {path}:\n{}", render(&f));
        assert_flagged(&f, EPRINTLN_RULE, line_of(EPRINTLN_BAD, "eprintln!"));
        assert_flagged(&f, EPRINTLN_RULE, line_of(EPRINTLN_BAD, "println!"));
        assert!(f[0].message.contains("rate-limited"), "{}", f[0]);
    }
}

#[test]
fn no_bare_eprintln_is_scoped() {
    // The CLI, benches, and the obs crate itself print freely.
    let f = only("main.rs", EPRINTLN_BAD, EPRINTLN_RULE);
    assert!(f.is_empty(), "findings:\n{}", render(&f));
    let f = only("obs/log.rs", EPRINTLN_BAD, EPRINTLN_RULE);
    assert!(f.is_empty(), "findings:\n{}", render(&f));
}

const EPRINTLN_NEAR: &str = r#"
pub fn report(eprintln: u64) -> u64 {
    // A local that merely shares the name, and a doc mention of
    // eprintln! in a comment, are not prints.
    eprintln + 1
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_print_freely() {
        eprintln!("debugging a fixture");
        println!("and stdout too");
    }
}
"#;

#[test]
fn no_bare_eprintln_exempts_non_macros_and_tests() {
    let f = only("net/server.rs", EPRINTLN_NEAR, EPRINTLN_RULE);
    assert!(f.is_empty(), "findings:\n{}", render(&f));
}

const EPRINTLN_ALLOW_OK: &str = r#"
pub fn banner(addr: &str) {
    // lint:allow(no-bare-eprintln): machine-parsed startup line on stdout
    println!("listening on {addr}");
}
"#;

const EPRINTLN_ALLOW_BARE: &str = r#"
pub fn banner(addr: &str) {
    // lint:allow(no-bare-eprintln)
    println!("listening on {addr}");
}
"#;

#[test]
fn no_bare_eprintln_suppression_round_trip() {
    let ok = lint_sources(&[("net/server.rs", EPRINTLN_ALLOW_OK)], None);
    assert!(ok.is_empty(), "findings:\n{}", render(&ok));

    let bare = lint_sources(&[("net/server.rs", EPRINTLN_ALLOW_BARE)], None);
    assert_eq!(bare.len(), 2, "findings:\n{}", render(&bare));
    assert_flagged(&bare, "suppression", line_of(EPRINTLN_ALLOW_BARE, "lint:allow"));
    assert_flagged(&bare, EPRINTLN_RULE, line_of(EPRINTLN_ALLOW_BARE, "println!"));
}

// ---------------------------------------------------------- suppressions

const HYGIENE: &str = r#"
// lint:allow(not-a-rule): confidently wrong
pub fn a() {}

// lint:allow missing the parenthesized rule entirely
pub fn b() {}
"#;

#[test]
fn suppression_hygiene_unknown_and_malformed() {
    let f = lint_sources(&[("util/misc.rs", HYGIENE)], None);
    assert_eq!(f.len(), 2, "findings:\n{}", render(&f));
    assert_flagged(&f, "suppression", line_of(HYGIENE, "not-a-rule"));
    assert!(f[0].message.contains("unknown rule"), "{}", f[0]);
    assert_flagged(&f, "suppression", line_of(HYGIENE, "missing the parenthesized"));
    assert!(f[1].message.contains("malformed"), "{}", f[1]);
}

const WRONG_RULE: &str = r#"
pub fn parse(frame: &[u8]) -> u8 {
    frame[0] // lint:allow(unchecked-micros-arith): names the wrong rule on purpose
}
"#;

#[test]
fn suppression_for_another_rule_does_not_suppress() {
    let f = lint_sources(&[("net/server.rs", WRONG_RULE)], None);
    assert_eq!(f.len(), 1, "findings:\n{}", render(&f));
    assert_flagged(&f, PANIC_RULE, line_of(WRONG_RULE, "frame[0]"));
}

#[test]
fn rule_registry_is_complete() {
    let names = symphony::lint::rule_names();
    for expected in [
        DRIFT_RULE,
        FLOAT_RULE,
        MICROS_RULE,
        PANIC_RULE,
        LOCK_RULE,
        CHANNEL_RULE,
        UNSAFE_RULE,
        RELAXED_RULE,
        EPRINTLN_RULE,
        "suppression",
    ] {
        assert!(names.contains(&expected), "missing rule `{expected}` in {names:?}");
    }
}

// ----------------------------------------------------------- tier-1 gate

/// The checked-in tree must lint clean — the in-process mirror of the
/// CI `symphony lint` gate, so a regression fails `cargo test` locally
/// before it ever reaches CI.
#[test]
fn lint_tree_is_clean() {
    let root = std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/rust/src"));
    let findings = symphony::lint::run(root, None).expect("walk rust/src");
    assert!(
        findings.is_empty(),
        "lint findings on the checked-in tree:\n{}",
        render(&findings)
    );
}
