//! Wire-transport semantics: `serve --remote-ranks`-equivalent
//! coordinators against a loopback `rank-server` must dispatch the
//! same work as in-process shards, the drain/attach autoscaler
//! protocol must round-trip as frames, a rank-server disconnect must
//! be surfaced (counted + logged) rather than silently wedging the
//! model workers, and — the survivability contract — a session killed
//! mid-load by a seeded [`FaultPlan`] must heal: reconnect, replay
//! registrations, and finish the workload with the exact same
//! no-loss/no-dup dispatch multiset as a clean run.

use std::collections::BTreeSet;
use std::io::{Read, Write};
use std::net::TcpListener;
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::time::{Duration, Instant};

use symphony::coordinator::{Completion, Coordinator, CoordinatorConfig, ToBackend};
use symphony::core::profile::LatencyProfile;
use symphony::core::time::Micros;
use symphony::core::types::{GpuId, ModelId, Request, RequestId};
use symphony::net::client::ReconnectPolicy;
use symphony::net::codec::{self, ServerPreamble, HELLO_LEN};
use symphony::net::faults::FaultPlan;
use symphony::net::server::{RankServer, RankServerConfig};

const N_MODELS: usize = 2;
const NUM_GPUS: usize = 2;

fn config(remote_ranks: Vec<String>) -> CoordinatorConfig {
    CoordinatorConfig {
        profiles: vec![LatencyProfile::new(0.2, 1.0); N_MODELS],
        num_gpus: NUM_GPUS,
        initial_gpus: None,
        rank_shards: 2,
        ingest_shards: 1,
        model_workers: Some(2),
        net_bound: Micros::from_millis_f64(1.0),
        exec_margin: Micros::ZERO,
        remote_ranks,
        busy_poll: false,
        pin_cores: false,
        reconnect: ReconnectPolicy::default(),
        fault_plan: FaultPlan::none(),
    }
}

fn spawn_server_with(
    shards: usize,
    max_sessions: usize,
    fault_plan: Arc<FaultPlan>,
) -> (String, std::thread::JoinHandle<()>) {
    let server = RankServer::bind(RankServerConfig {
        listen: "127.0.0.1:0".into(),
        shards,
        gpus: 0..NUM_GPUS as u32,
        max_sessions: Some(max_sessions),
        busy_poll: false,
        pin_cores: false,
        fault_plan,
        metrics_listen: None,
    })
    .expect("bind rank server");
    let addr = server.local_addr().to_string();
    let h = std::thread::spawn(move || server.run().expect("rank server run"));
    (addr, h)
}

fn spawn_server(shards: usize) -> (String, std::thread::JoinHandle<()>) {
    spawn_server_with(shards, 1, FaultPlan::none())
}

/// Run one seeded workload through a coordinator and return
/// (dispatched ids, dropped ids, rank_disconnects). Deterministic
/// workload; generous SLO so nothing sheds.
fn run_workload(remote: bool, n: u64) -> (Vec<u64>, Vec<u64>, u64) {
    let (remote_ranks, server) = if remote {
        let (addr, h) = spawn_server(2);
        (vec![addr], Some(h))
    } else {
        (Vec::new(), None)
    };
    let mut backend_txs = Vec::new();
    let mut backend_rxs = Vec::new();
    for _ in 0..NUM_GPUS {
        let (tx, rx) = channel::<ToBackend>();
        backend_txs.push(tx);
        backend_rxs.push(rx);
    }
    let (comp_tx, comp_rx) = channel::<Completion>();
    let coord = Coordinator::spawn(config(remote_ranks), backend_txs, comp_tx);
    let slo = Micros::from_millis_f64(2_000.0);
    for i in 0..n {
        let now = coord.clock.now();
        coord.submit(Request {
            id: RequestId(i),
            model: ModelId((i % N_MODELS as u64) as u32),
            arrival: now,
            deadline: now + slo,
        });
        if i % 16 == 15 {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    // Wait until every request is dispatched or dropped.
    let mut dispatched: Vec<u64> = Vec::new();
    let mut dropped: Vec<u64> = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(20);
    while (dispatched.len() + dropped.len()) < n as usize && Instant::now() < deadline {
        for rx in &backend_rxs {
            for msg in rx.try_iter() {
                if let ToBackend::Execute { requests, .. } = msg {
                    dispatched.extend(requests.iter().map(|r| r.id.0));
                }
            }
        }
        for c in comp_rx.try_iter() {
            if let Completion::Dropped(rs) = c {
                dropped.extend(rs.iter().map(|r| r.id.0));
            }
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    let disconnects = coord.rank_disconnects();
    coord.shutdown();
    if let Some(h) = server {
        let _ = h.join();
    }
    dispatched.sort_unstable();
    dropped.sort_unstable();
    (dispatched, dropped, disconnects)
}

/// The acceptance criterion: on an identical seeded workload the
/// remote-rank coordinator produces the same dispatch multiset as the
/// in-process one — every submitted request dispatched exactly once,
/// none dropped, none duplicated, on either side of the wire.
#[test]
fn remote_ranks_match_inprocess_dispatch_multiset() {
    let n = 400u64;
    let (local_disp, local_drop, local_disc) = run_workload(false, n);
    let (remote_disp, remote_drop, remote_disc) = run_workload(true, n);
    assert_eq!(local_disc, 0);
    assert_eq!(remote_disc, 0, "clean run must not count a disconnect");
    assert!(local_drop.is_empty(), "in-process dropped {:?}", local_drop.len());
    assert!(remote_drop.is_empty(), "remote dropped {:?}", remote_drop.len());
    let expect: Vec<u64> = (0..n).collect();
    assert_eq!(local_disp, expect, "in-process: every id exactly once");
    assert_eq!(
        remote_disp, expect,
        "remote: same dispatch multiset as in-process"
    );
}

/// Drain/attach over the wire: `ClusterCtl::drain` against a remote
/// shard must come back as a `DrainAck` frame feeding the caller's
/// `Sender<GpuId>`, the drained GPU must stop being granted, and a
/// subsequent `Attach` frame must revive it.
#[test]
fn drain_ack_and_attach_round_trip_the_wire() {
    let (addr, server) = spawn_server(1);
    let mut backend_txs = Vec::new();
    let mut backend_rxs: Vec<Receiver<ToBackend>> = Vec::new();
    for _ in 0..NUM_GPUS {
        let (tx, rx) = channel::<ToBackend>();
        backend_txs.push(tx);
        backend_rxs.push(rx);
    }
    let (comp_tx, _comp_rx) = channel::<Completion>();
    let coord = Coordinator::spawn(config(vec![addr]), backend_txs, comp_tx);
    let ctl = coord.cluster_ctl();

    // Drain the high GPU while idle: the ack must round-trip promptly.
    let (ack_tx, ack_rx) = channel::<GpuId>();
    ctl.drain(GpuId(1), ack_tx).expect("drain over the wire");
    let acked = ack_rx
        .recv_timeout(Duration::from_secs(5))
        .expect("DrainAck frame must come back");
    assert_eq!(acked, GpuId(1));

    // With GPU 1 retired, all work lands on GPU 0.
    let slo = Micros::from_millis_f64(2_000.0);
    for i in 0..40u64 {
        let now = coord.clock.now();
        coord.submit(Request {
            id: RequestId(i),
            model: ModelId((i % 2) as u32),
            arrival: now,
            deadline: now + slo,
        });
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut on_gpu0 = 0usize;
    while on_gpu0 < 40 && Instant::now() < deadline {
        for msg in backend_rxs[0].try_iter() {
            if let ToBackend::Execute { requests, .. } = msg {
                on_gpu0 += requests.len();
            }
        }
        assert!(
            backend_rxs[1].try_iter().next().is_none(),
            "drained GPU 1 must never be granted"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(on_gpu0, 40, "all work on the surviving GPU");

    // Attach revives it: eventually GPU 1 executes again.
    ctl.attach(GpuId(1)).expect("attach over the wire");
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut gpu1_used = false;
    let mut i = 1_000u64;
    while !gpu1_used && Instant::now() < deadline {
        let now = coord.clock.now();
        for _ in 0..8 {
            coord.submit(Request {
                id: RequestId(i),
                model: ModelId((i % 2) as u32),
                arrival: now,
                deadline: now + slo,
            });
            i += 1;
        }
        std::thread::sleep(Duration::from_millis(5));
        gpu1_used = backend_rxs[1].try_iter().next().is_some();
    }
    assert!(gpu1_used, "attached GPU must serve again");
    assert_eq!(coord.rank_disconnects(), 0);
    coord.shutdown();
    let _ = server.join();
}

/// A rank server that vanishes mid-session is *surfaced*: the
/// disconnect counter increments (and the event is logged), sends into
/// the dead tier fail fast, and shutdown completes instead of wedging.
/// The stub here handshakes like a real server, then drops the socket.
#[test]
fn server_disconnect_is_counted_not_wedged() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stub = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        (&stream)
            .write_all(&codec::encode_preamble(&ServerPreamble {
                shards: 2,
                gpu_lo: 0,
                gpu_hi: NUM_GPUS as u32,
                session: 1,
            }))
            .unwrap();
        let mut hello = [0u8; HELLO_LEN];
        (&stream).read_exact(&mut hello).unwrap();
        // Handshake complete — now vanish.
        drop(stream);
    });
    let mut backend_txs = Vec::new();
    for _ in 0..NUM_GPUS {
        let (tx, _rx) = channel::<ToBackend>();
        backend_txs.push(tx);
    }
    let (comp_tx, _comp_rx) = channel::<Completion>();
    // Reconnect off: this test pins down the terminal-death semantics
    // (the reconnect path has its own test below).
    let mut cfg = config(vec![addr]);
    cfg.reconnect = ReconnectPolicy::disabled();
    let coord = Coordinator::spawn(cfg, backend_txs, comp_tx);
    stub.join().unwrap();

    // The reader notices the EOF and counts it.
    let deadline = Instant::now() + Duration::from_secs(5);
    while coord.rank_disconnects() == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(coord.rank_disconnects(), 1, "disconnect must be counted");

    // A drain against the dead tier must fail fast, not hang: either
    // the port rejects the send outright, or the parked ack sender was
    // dropped by the disconnect path — a blocking recv sees
    // Disconnected immediately, like a dead in-process shard.
    let ctl = coord.cluster_ctl();
    let (ack_tx, ack_rx) = channel::<GpuId>();
    let _ = ctl.drain(GpuId(0), ack_tx);
    assert_eq!(
        ack_rx.recv_timeout(Duration::from_millis(200)),
        Err(std::sync::mpsc::RecvTimeoutError::Disconnected),
        "pending drain ack must disconnect, not wedge"
    );

    // Submissions into the dead tier do not wedge anything: workers
    // fail fast on their next registration and later submissions are
    // counted as dropped, all within a bounded shutdown.
    let now = coord.clock.now();
    for i in 0..32u64 {
        coord.submit(Request {
            id: RequestId(i),
            model: ModelId((i % 2) as u32),
            arrival: now,
            deadline: now + Micros::from_millis_f64(50.0),
        });
    }
    std::thread::sleep(Duration::from_millis(50));
    let (front, stats) = coord.shutdown_stats();
    assert_eq!(front.rank_disconnects, 1);
    assert_eq!(stats.grants, 0, "nothing can be granted by a dead tier");
}

/// Misconfiguration fails the spawn, not the first registration: a
/// remote tier that does not cover the cluster's GPU range is an
/// error from `try_spawn`.
#[test]
fn topology_mismatch_fails_spawn() {
    let (addr, server) = spawn_server(1); // covers 0..NUM_GPUS
    let mut cfg = config(vec![addr]);
    cfg.num_gpus = NUM_GPUS + 3; // cluster claims more GPUs than served
    let mut backend_txs = Vec::new();
    for _ in 0..cfg.num_gpus {
        let (tx, _rx) = channel::<ToBackend>();
        backend_txs.push(tx);
    }
    let (comp_tx, _comp_rx) = channel::<Completion>();
    let err = Coordinator::try_spawn(cfg, backend_txs, comp_tx);
    assert!(err.is_err(), "range mismatch must fail spawn");
    // The server saw one (aborted) session; let it exit.
    let _ = server.join();
}

/// The survivability contract, end to end: a rank-server session
/// killed mid-load by a seeded fault plan must not lose or duplicate
/// work. The server's timed killer drops the socket a few ms into the
/// run; the client fences the dead session, redials, replays its
/// registrations (`ToModel::Reregister`), and grants resume in
/// session 2. Every submitted id is dispatched exactly once, the
/// disconnect and the reconnect are both counted, and shutdown stays
/// bounded.
#[test]
fn killed_session_reconnects_without_loss_or_duplication() {
    let n = 600u64;
    // Session 1 dies 3 ms in (well before the ≳60 ms of simulated GPU
    // time the workload needs); session 2 runs clean (`times=1`).
    let plan = FaultPlan::parse("seed=7,kill-after-us=3000,times=1").expect("plan");
    let (addr, server) = spawn_server_with(2, 2, plan);
    let mut backend_txs = Vec::new();
    let mut backend_rxs = Vec::new();
    for _ in 0..NUM_GPUS {
        let (tx, rx) = channel::<ToBackend>();
        backend_txs.push(tx);
        backend_rxs.push(rx);
    }
    let (comp_tx, comp_rx) = channel::<Completion>();
    // Tight backoff so the redial lands well inside the test budget.
    let mut cfg = config(vec![addr]);
    cfg.reconnect = ReconnectPolicy {
        enabled: true,
        backoff_base: Duration::from_millis(10),
        backoff_cap: Duration::from_millis(100),
        dead_after: Duration::from_secs(10),
    };
    let coord = Coordinator::spawn(cfg, backend_txs, comp_tx);
    let slo = Micros::from_millis_f64(10_000.0);
    for i in 0..n {
        let now = coord.clock.now();
        coord.submit(Request {
            id: RequestId(i),
            model: ModelId((i % N_MODELS as u64) as u32),
            arrival: now,
            deadline: now + slo,
        });
        if i % 16 == 15 {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    let mut dispatched: Vec<u64> = Vec::new();
    let mut dropped = 0usize;
    let deadline = Instant::now() + Duration::from_secs(30);
    while (dispatched.len() + dropped) < n as usize && Instant::now() < deadline {
        for rx in &backend_rxs {
            for msg in rx.try_iter() {
                if let ToBackend::Execute { requests, .. } = msg {
                    dispatched.extend(requests.iter().map(|r| r.id.0));
                }
            }
        }
        for c in comp_rx.try_iter() {
            if let Completion::Dropped(rs) = c {
                dropped += rs.len();
            }
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    let (front, stats) = coord.shutdown_stats();
    let _ = server.join();
    assert_eq!(dropped, 0, "generous SLO + fast reconnect: nothing sheds");
    dispatched.sort_unstable();
    let expect: Vec<u64> = (0..n).collect();
    assert_eq!(dispatched, expect, "every id exactly once across the kill");
    assert_eq!(front.rank_disconnects, 1, "the seeded kill is counted once");
    assert_eq!(
        front.rank_disconnect_causes.io, 1,
        "a socket kill surfaces as an io-cause disconnect"
    );
    assert_eq!(front.rank_reconnects, 1, "the redial healed into session 2");
    assert!(stats.grants > 0, "grants resumed across the reconnect");
}

/// Ids used in sets above stay unique across helper runs.
#[test]
fn workload_ids_are_a_set() {
    let n = 64;
    let (disp, drop, _) = run_workload(false, n);
    let uniq: BTreeSet<u64> = disp.iter().chain(drop.iter()).copied().collect();
    assert_eq!(uniq.len() as u64, n);
}
