//! Observability integration: flight-recorder span accounting over
//! real serving runs (in-process and loopback-wire), and the live
//! `/metrics` endpoint end to end — exposition shape, and counter
//! monotonicity across two scrapes of one run.
//!
//! The recorder is process-global (one session at a time), so every
//! test that installs a session holds `RECORDER` for its duration;
//! serve() is then configured with `trace_sample: 0` and the ambient
//! session captures its taps.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::Duration;

use symphony::core::profile::ModelSpec;
use symphony::net::faults::FaultPlan;
use symphony::net::server::{RankServer, RankServerConfig};
use symphony::obs::prom::Prom;
use symphony::obs::trace::{self, Stage};
use symphony::serve::{serve, BackendKind, ServeConfig};

static RECORDER: Mutex<()> = Mutex::new(());

fn base_cfg() -> ServeConfig {
    ServeConfig {
        models: vec![
            ModelSpec::new("a", 0.2, 2.0, 50.0),
            ModelSpec::new("b", 0.2, 2.0, 50.0),
        ],
        num_gpus: 2,
        initial_gpus: None,
        rank_shards: 2,
        ingest_shards: 1,
        model_workers: None,
        remote_ranks: Vec::new(),
        total_rate: 300.0,
        rate_phases: Vec::new(),
        duration: Duration::from_millis(600),
        backend: BackendKind::Sleep,
        autoscale: None,
        busy_poll: false,
        pin_cores: false,
        seed: 17,
        fault_plan: FaultPlan::none(),
        trace_sample: 0,
        trace_out: None,
        metrics_listen: None,
    }
}

/// Every sampled request that completed must respect the span
/// accounting invariants, and the lifecycle stages the pipeline
/// promises must all actually appear in the dump.
#[test]
fn trace_invariants_hold_on_in_process_run() {
    let _g = RECORDER.lock().unwrap();
    let session = trace::install(1).expect("recorder free under RECORDER lock");
    let report = serve(base_cfg()).unwrap();
    let dump = session.finish();

    assert!(report.completed > 0, "{report:?}");
    assert!(!dump.events.is_empty(), "tracing captured nothing");
    dump.check_invariants().unwrap_or_else(|e| panic!("invariant violated: {e}"));
    for stage in [
        Stage::Submit,
        Stage::IngestBin,
        Stage::WorkerRecv,
        Stage::CandReg,
        Stage::RankGrant,
        Stage::GrantRecv,
        Stage::Dispatch,
        Stage::Complete,
    ] {
        assert!(
            dump.events.iter().any(|e| e.stage == stage),
            "no {stage:?} event in {} events",
            dump.events.len()
        );
    }
    // The hop table the report would carry: full pipeline order, every
    // hop populated.
    let hops = dump.hop_breakdown();
    assert!(hops.len() >= 5, "hop table too sparse: {hops:?}");
    assert!(hops.iter().all(|h| h.count > 0));
}

/// Same contract across the wire: a loopback rank-server run must add
/// the wire-side stages (Candidate tx, Granted rx) and still satisfy
/// the accounting invariants on one shared time axis.
#[test]
fn trace_invariants_hold_on_loopback_wire_run() {
    let _g = RECORDER.lock().unwrap();
    let server = RankServer::bind(RankServerConfig {
        listen: "127.0.0.1:0".into(),
        shards: 1,
        gpus: 0..2,
        max_sessions: Some(1),
        busy_poll: false,
        pin_cores: false,
        fault_plan: FaultPlan::none(),
        metrics_listen: None,
    })
    .expect("bind loopback rank server");
    let addr = server.local_addr().to_string();
    let server_h = std::thread::spawn(move || server.run().expect("rank server run"));

    let session = trace::install(1).expect("recorder free under RECORDER lock");
    let report = serve(ServeConfig {
        remote_ranks: vec![addr],
        ..base_cfg()
    })
    .unwrap();
    let dump = session.finish();
    server_h.join().expect("server thread");

    assert!(report.grants > 0, "{report:?}");
    dump.check_invariants().unwrap_or_else(|e| panic!("invariant violated: {e}"));
    for stage in [Stage::WireCandTx, Stage::WireGrantRx, Stage::RankGrant] {
        assert!(
            dump.events.iter().any(|e| e.stage == stage),
            "no {stage:?} event on the wire run"
        );
    }
}

/// Exact exposition golden: family headers, label escaping, and sample
/// ordering are byte-stable — what a Prometheus scraper parses.
#[test]
fn prometheus_exposition_golden() {
    let mut p = Prom::new();
    p.family("symphony_grants_total", "counter", "GPU grants issued.");
    p.sample("symphony_grants_total", &[("shard", "0")], 41);
    p.sample("symphony_grants_total", &[("shard", "1")], 1);
    p.family("symphony_queue_depth", "gauge", "Requests queued.");
    p.sample("symphony_queue_depth", &[], 7);
    assert_eq!(
        p.finish(),
        "# HELP symphony_grants_total GPU grants issued.\n\
         # TYPE symphony_grants_total counter\n\
         symphony_grants_total{shard=\"0\"} 41\n\
         symphony_grants_total{shard=\"1\"} 1\n\
         # HELP symphony_queue_depth Requests queued.\n\
         # TYPE symphony_queue_depth gauge\n\
         symphony_queue_depth 7\n"
    );
}

/// One HTTP scrape against `addr`, returning the exposition body.
fn scrape(addr: &str) -> String {
    let mut stream = TcpStream::connect(addr).expect("connect metrics listener");
    stream
        .write_all(b"GET /metrics HTTP/1.1\r\nHost: test\r\n\r\n")
        .expect("send request");
    let mut raw = String::new();
    stream.read_to_string(&mut raw).expect("read response");
    assert!(raw.starts_with("HTTP/1.1 200"), "bad response: {raw:.100}");
    assert!(
        raw.contains("text/plain; version=0.0.4"),
        "missing exposition content-type: {raw:.300}"
    );
    let (_, body) = raw.split_once("\r\n\r\n").expect("header/body split");
    body.to_string()
}

/// Value of the first sample line for `name` (any labels) in `body`.
fn metric(body: &str, name: &str) -> u64 {
    body.lines()
        .find(|l| !l.starts_with('#') && l.starts_with(name))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("metric {name} not in scrape:\n{body}"))
}

/// Scrape a live run twice: the page must carry the full metric
/// surface, and cumulative counters must be monotone between scrapes.
#[test]
fn metrics_endpoint_scrapes_live_and_monotonic() {
    let addr = "127.0.0.1:17891";
    let run = std::thread::spawn(move || {
        serve(ServeConfig {
            duration: Duration::from_millis(1500),
            metrics_listen: Some(addr.to_string()),
            ..base_cfg()
        })
        .unwrap()
    });
    // First scrape early in the run, second near its end.
    std::thread::sleep(Duration::from_millis(500));
    let first = scrape(addr);
    std::thread::sleep(Duration::from_millis(600));
    let second = scrape(addr);
    let report = run.join().expect("serve run");
    assert!(report.completed > 0, "{report:?}");

    for name in [
        "symphony_requests_good_total",
        "symphony_requests_bad_total",
        "symphony_dropped_submits_total",
        "symphony_grants_total{shard=\"0\"}",
        "symphony_mis_steers_total{shard=\"0\"}",
        "symphony_rank_disconnects_total{cause=\"io\"}",
        "symphony_rank_reconnects_total",
        "symphony_fenced_frames_total",
        "symphony_queue_depth",
        "symphony_ring_depth{tier=\"ingest\",idx=\"0\"}",
        "symphony_ring_hwm{tier=\"model\",idx=\"0\"}",
        "symphony_ring_hwm{tier=\"rank\",idx=\"1\"}",
        "symphony_gpus_active",
        "symphony_autoscale_epochs_total",
        "symphony_trace_shed_total",
    ] {
        assert!(
            first.lines().any(|l| l.starts_with(name)),
            "metric {name} missing from scrape:\n{first}"
        );
    }
    let g1 = metric(&first, "symphony_requests_good_total");
    let g2 = metric(&second, "symphony_requests_good_total");
    assert!(g2 >= g1, "good_total went backwards: {g1} -> {g2}");
    assert!(g2 > 0, "no goodput visible by the second scrape");
    let grants1 = metric(&first, "symphony_grants_total");
    let grants2 = metric(&second, "symphony_grants_total");
    assert!(grants2 >= grants1, "grants went backwards: {grants1} -> {grants2}");
    assert_eq!(metric(&second, "symphony_gpus_active"), 2);
}

/// The rank server's own scrape surface: session counters appear and
/// count the one session the run used.
#[test]
fn rank_server_metrics_count_sessions() {
    let addr = "127.0.0.1:17892";
    let server = RankServer::bind(RankServerConfig {
        listen: "127.0.0.1:0".into(),
        shards: 1,
        gpus: 0..2,
        max_sessions: Some(1),
        busy_poll: false,
        pin_cores: false,
        fault_plan: FaultPlan::none(),
        metrics_listen: Some(addr.to_string()),
    })
    .expect("bind loopback rank server");
    let rank_addr = server.local_addr().to_string();
    let server_h = std::thread::spawn(move || server.run().expect("rank server run"));
    // Give the metrics listener a beat to bind before scraping.
    std::thread::sleep(Duration::from_millis(100));
    let idle = scrape(addr);
    assert_eq!(metric(&idle, "symphony_server_sessions_total"), 0);

    // Scrape mid-run: the server's listener lives only as long as
    // `run()`, which returns (max_sessions=1) once the client hangs up.
    let run = std::thread::spawn(move || {
        serve(ServeConfig {
            remote_ranks: vec![rank_addr],
            ..base_cfg()
        })
        .unwrap()
    });
    std::thread::sleep(Duration::from_millis(300));
    let live = scrape(addr);
    let report = run.join().expect("serve run");
    server_h.join().expect("server thread");
    assert!(report.grants > 0, "{report:?}");
    assert_eq!(metric(&live, "symphony_server_sessions_total"), 1);
    assert_eq!(metric(&live, "symphony_server_reconnected_sessions_total"), 0);
    assert!(
        metric(&live, "symphony_server_grants_total") > 0,
        "grants invisible server-side:\n{live}"
    );
}
