//! Integration tests pinning the paper's worked examples (§3.3, Figs
//! 4/5) and the analytical relationships they illustrate.

use symphony::core::time::Micros;
use symphony::harness::experiments::worked_example_workload;
use symphony::harness::SystemKind;
use symphony::sim::{Engine, SimConfig};

fn run(sys: SystemKind, skip: bool, n: usize) -> symphony::sim::SimResult<Box<dyn symphony::scheduler::Scheduler>> {
    let (models, workload) = worked_example_workload(n, skip);
    let cfg = SimConfig::new(3, Micros::from_secs_f64(0.5)).trace(true);
    Engine::new(workload, sys.build(&models, 3, Micros::ZERO), cfg).run()
}

/// Fig 4: the first batch is {R1..R4}, dispatched inside the window
/// [frontrun=2, latest=3] when R4 arrives at 2.25; the pattern then
/// staggers across the 3 GPUs with batch size 4 forever.
#[test]
fn fig4_trace_exact() {
    let res = run(SystemKind::Symphony, false, 48);
    let first = &res.trace[0];
    assert_eq!(first.size, 4);
    assert_eq!(first.start, Micros::from_millis_f64(2.25));
    assert_eq!(first.gpu.0, 0, "min-id GPU first");
    // Steady state: all batches size 4, no drops, staggered GPUs.
    assert!(res.trace.iter().all(|t| t.size == 4));
    for w in res.trace.windows(2) {
        assert_ne!(w[0].gpu, w[1].gpu);
    }
    assert_eq!(res.metrics.per_model[0].dropped, 0);
    assert_eq!(res.metrics.per_model[0].late, 0);
}

/// Fig 4 cadence: consecutive dispatches are 3 ms apart (= ℓ(4)/3 GPUs
/// = staggered offset) once the pattern is established.
#[test]
fn fig4_staggered_cadence() {
    let res = run(SystemKind::Symphony, false, 48);
    let starts: Vec<f64> = res.trace.iter().map(|t| t.start.as_millis_f64()).collect();
    for w in starts.windows(2).skip(1) {
        let gap = w[1] - w[0];
        assert!((gap - 3.0).abs() < 0.26, "gap {gap}");
    }
}

/// Fig 5: with R13–R15 missing, eager degrades (drops) while deferred
/// loses only the requests that were never sent and recovers the
/// staggered pattern.
#[test]
fn fig5_deferred_recovers_eager_degrades() {
    let eager = run(SystemKind::Eager, true, 72);
    let deferred = run(SystemKind::Symphony, true, 72);
    let e = &eager.metrics.per_model[0];
    let d = &deferred.metrics.per_model[0];
    assert!(
        d.good > e.good,
        "deferred good {} vs eager good {}",
        d.good,
        e.good
    );
    assert!(
        d.dropped < e.dropped,
        "deferred dropped {} vs eager {}",
        d.dropped,
        e.dropped
    );
    // Deferred regains batch-4 staggering by the tail of the trace (the
    // very last batch only collects the workload's leftover stragglers).
    let tail: Vec<u32> = deferred
        .trace
        .iter()
        .rev()
        .skip(1)
        .take(5)
        .map(|t| t.size)
        .collect();
    assert!(tail.iter().all(|&s| s == 4), "tail {tail:?}");
}

/// §3.3 goodput upper bound: measured Symphony goodput never exceeds
/// the staggered-execution analytical bound, and gets within 15%.
#[test]
fn staggered_bound_respected() {
    use symphony::core::model_zoo;
    use symphony::harness::GoodputExperiment;
    use symphony::scheduler::analytical;
    let model = model_zoo::resnet50_table2();
    let bound = analytical::staggered(&model.profile, model.slo, 8).throughput;
    let exp = GoodputExperiment::new(vec![model], 8).sim_secs(6.0);
    let got = exp
        .goodput(|e| SystemKind::Symphony.build(&e.models, e.num_gpus, Micros::ZERO))
        .goodput;
    assert!(got <= bound * 1.02, "goodput {got} exceeds bound {bound}");
    assert!(got >= bound * 0.85, "goodput {got} too far below bound {bound}");
}
