//! Property-based integration tests: randomized workloads through every
//! scheduler, asserting the invariants that must hold regardless of
//! policy (accounting conservation, no double-booking — enforced by
//! engine asserts —, SLO discipline, determinism, consolidation).

use symphony::core::model_zoo::GpuKind;
use symphony::core::time::Micros;
use symphony::core::{model_zoo, profile::ModelSpec};
use symphony::harness::SystemKind;
use symphony::prop_assert;
use symphony::sim::{Engine, SimConfig};
use symphony::util::proptest::{check, default_cases};
use symphony::util::rng::Rng;
use symphony::workload::WorkloadSpec;

fn random_models(rng: &mut Rng) -> Vec<ModelSpec> {
    let n = 1 + rng.below(6) as usize;
    (0..n)
        .map(|i| {
            let alpha = rng.range_f64(0.3, 6.0);
            let beta = rng.range_f64(0.1, 15.0);
            // SLO large enough that at least batch 2 fits.
            let min_slo = 2.0 * alpha + beta;
            let slo = rng.range_f64(min_slo * 1.2, min_slo * 4.0);
            ModelSpec::new(&format!("m{i}"), alpha, beta, slo)
        })
        .collect()
}

fn all_systems() -> Vec<SystemKind> {
    vec![
        SystemKind::Symphony,
        SystemKind::Clockwork,
        SystemKind::Nexus { frontends: 1 },
        SystemKind::Shepherd,
        SystemKind::Eager,
        SystemKind::Timeout {
            k: Micros::from_millis_f64(3.0),
        },
    ]
}

/// Every submitted request reaches exactly one terminal state and the
/// per-model counters conserve.
#[test]
fn prop_accounting_conserves() {
    check("accounting", default_cases(), |rng| {
        let models = random_models(rng);
        let gpus = 1 + rng.below(12) as usize;
        let rate = rng.range_f64(50.0, 4_000.0);
        let sys = all_systems()[rng.below(6) as usize];
        let spec = WorkloadSpec::new(models.clone(), rate).seed(rng.next_u64());
        let cfg = SimConfig::new(gpus, Micros::from_secs_f64(2.0)).seed(rng.next_u64());
        let res = Engine::new(spec.build(), sys.build(&models, gpus, Micros::ZERO), cfg).run();
        let m = &res.metrics;
        for (i, pm) in m.per_model.iter().enumerate() {
            let total = pm.good + pm.late + pm.dropped + pm.unfinished;
            prop_assert!(
                pm.batch_hist.total() == pm.good + pm.late,
                "model {i}: batch-hist {} != completed {}",
                pm.batch_hist.total(),
                pm.good + pm.late
            );
            prop_assert!(total > 0 || rate < 100.0, "model {i} got nothing");
        }
        Ok(())
    });
}

/// The deferred scheduler never finishes a request after its deadline —
/// it must drop instead (the schedulable window guarantees it).
#[test]
fn prop_deferred_never_late() {
    check("deferred_never_late", default_cases(), |rng| {
        let models = random_models(rng);
        let gpus = 1 + rng.below(12) as usize;
        let rate = rng.range_f64(50.0, 6_000.0);
        let spec = WorkloadSpec::new(models.clone(), rate)
            .gamma_shape(if rng.f64() < 0.5 { 1.0 } else { 0.2 })
            .seed(rng.next_u64());
        let cfg = SimConfig::new(gpus, Micros::from_secs_f64(2.0));
        let res = Engine::new(
            spec.build(),
            SystemKind::Symphony.build(&models, gpus, Micros::ZERO),
            cfg,
        )
        .run();
        let late: u64 = res.metrics.per_model.iter().map(|pm| pm.late).sum();
        prop_assert!(late == 0, "deferred produced {late} late completions");
        Ok(())
    });
}

/// Same seed ⇒ bit-identical outcome counts (full determinism).
#[test]
fn prop_deterministic() {
    check("determinism", 16, |rng| {
        let models = random_models(rng);
        let gpus = 1 + rng.below(8) as usize;
        let rate = rng.range_f64(100.0, 3_000.0);
        let seed = rng.next_u64();
        let sys = all_systems()[rng.below(6) as usize];
        let run = || {
            let spec = WorkloadSpec::new(models.clone(), rate).seed(seed);
            let cfg = SimConfig::new(gpus, Micros::from_secs_f64(1.5)).seed(seed);
            let res =
                Engine::new(spec.build(), sys.build(&models, gpus, Micros::ZERO), cfg).run();
            res.metrics
                .per_model
                .iter()
                .map(|pm| (pm.good, pm.late, pm.dropped))
                .collect::<Vec<_>>()
        };
        prop_assert!(run() == run(), "non-deterministic run for {}", sys.label());
        Ok(())
    });
}

/// Symphony's min-id GPU rule consolidates: at light load, the highest
/// GPU ids do no work at all.
#[test]
fn prop_consolidation() {
    check("consolidation", 24, |rng| {
        let models = vec![model_zoo::resnet50_table2()];
        let gpus = 8;
        // Light load: well under one GPU's capacity.
        let rate = rng.range_f64(20.0, 120.0);
        let spec = WorkloadSpec::new(models.clone(), rate).seed(rng.next_u64());
        let cfg = SimConfig::new(gpus, Micros::from_secs_f64(3.0));
        let res = Engine::new(
            spec.build(),
            SystemKind::Symphony.build(&models, gpus, Micros::ZERO),
            cfg,
        )
        .run();
        let used = res.metrics.gpus_used();
        prop_assert!(used <= 2, "light load used {used} of {gpus} GPUs");
        Ok(())
    });
}

/// Batch sizes never exceed what the SLO admits: ℓ(b) ≤ SLO for every
/// executed batch, for every scheduler.
#[test]
fn prop_batches_fit_slo() {
    check("batches_fit_slo", default_cases(), |rng| {
        let models = random_models(rng);
        let gpus = 1 + rng.below(8) as usize;
        let rate = rng.range_f64(100.0, 5_000.0);
        let sys = all_systems()[rng.below(6) as usize];
        let spec = WorkloadSpec::new(models.clone(), rate).seed(rng.next_u64());
        let cfg = SimConfig::new(gpus, Micros::from_secs_f64(1.5)).trace(true);
        let res = Engine::new(spec.build(), sys.build(&models, gpus, Micros::ZERO), cfg).run();
        for t in &res.trace {
            let m = &models[t.model.0 as usize];
            prop_assert!(
                m.profile.latency(t.size) <= m.slo,
                "{}: batch {} of {} exceeds SLO",
                sys.label(),
                t.size,
                m.name
            );
        }
        Ok(())
    });
}

/// Under Gamma(0.1) burstiness the deferred scheduler still satisfies
/// its feasibility discipline at low rates (sanity under the paper's
/// harshest arrival pattern).
#[test]
fn prop_bursty_low_load_clean() {
    check("bursty_low_load", 24, |rng| {
        let models = model_zoo::resnet_like_variants(4, 50.0, GpuKind::Gtx1080Ti);
        let spec = WorkloadSpec::new(models.clone(), 200.0)
            .gamma_shape(0.1)
            .seed(rng.next_u64());
        let cfg = SimConfig::new(8, Micros::from_secs_f64(3.0));
        let res = Engine::new(
            spec.build(),
            SystemKind::Symphony.build(&models, 8, Micros::ZERO),
            cfg,
        )
        .run();
        let bad = res.metrics.bad_fraction();
        prop_assert!(bad < 0.05, "bad fraction {bad} at light bursty load");
        Ok(())
    });
}
