//! Property-based integration tests: randomized workloads through every
//! scheduler, asserting the invariants that must hold regardless of
//! policy (accounting conservation, no double-booking — enforced by
//! engine asserts —, SLO discipline, determinism, consolidation).

use symphony::core::model_zoo::GpuKind;
use symphony::core::time::Micros;
use symphony::core::{model_zoo, profile::ModelSpec};
use symphony::harness::SystemKind;
use symphony::prop_assert;
use symphony::sim::{Engine, SimConfig};
use symphony::util::proptest::{check, default_cases};
use symphony::util::rng::Rng;
use symphony::workload::WorkloadSpec;

fn random_models(rng: &mut Rng) -> Vec<ModelSpec> {
    let n = 1 + rng.below(6) as usize;
    (0..n)
        .map(|i| {
            let alpha = rng.range_f64(0.3, 6.0);
            let beta = rng.range_f64(0.1, 15.0);
            // SLO large enough that at least batch 2 fits.
            let min_slo = 2.0 * alpha + beta;
            let slo = rng.range_f64(min_slo * 1.2, min_slo * 4.0);
            ModelSpec::new(&format!("m{i}"), alpha, beta, slo)
        })
        .collect()
}

fn all_systems() -> Vec<SystemKind> {
    vec![
        SystemKind::Symphony,
        SystemKind::Clockwork,
        SystemKind::Nexus { frontends: 1 },
        SystemKind::Shepherd,
        SystemKind::Eager,
        SystemKind::Timeout {
            k: Micros::from_millis_f64(3.0),
        },
    ]
}

/// Every submitted request reaches exactly one terminal state and the
/// per-model counters conserve.
#[test]
fn prop_accounting_conserves() {
    check("accounting", default_cases(), |rng| {
        let models = random_models(rng);
        let gpus = 1 + rng.below(12) as usize;
        let rate = rng.range_f64(50.0, 4_000.0);
        let sys = all_systems()[rng.below(6) as usize];
        let spec = WorkloadSpec::new(models.clone(), rate).seed(rng.next_u64());
        let cfg = SimConfig::new(gpus, Micros::from_secs_f64(2.0)).seed(rng.next_u64());
        let res = Engine::new(spec.build(), sys.build(&models, gpus, Micros::ZERO), cfg).run();
        let m = &res.metrics;
        for (i, pm) in m.per_model.iter().enumerate() {
            let total = pm.good + pm.late + pm.dropped + pm.unfinished;
            prop_assert!(
                pm.batch_hist.total() == pm.good + pm.late,
                "model {i}: batch-hist {} != completed {}",
                pm.batch_hist.total(),
                pm.good + pm.late
            );
            prop_assert!(total > 0 || rate < 100.0, "model {i} got nothing");
        }
        Ok(())
    });
}

/// The deferred scheduler never finishes a request after its deadline —
/// it must drop instead (the schedulable window guarantees it).
#[test]
fn prop_deferred_never_late() {
    check("deferred_never_late", default_cases(), |rng| {
        let models = random_models(rng);
        let gpus = 1 + rng.below(12) as usize;
        let rate = rng.range_f64(50.0, 6_000.0);
        let spec = WorkloadSpec::new(models.clone(), rate)
            .gamma_shape(if rng.f64() < 0.5 { 1.0 } else { 0.2 })
            .seed(rng.next_u64());
        let cfg = SimConfig::new(gpus, Micros::from_secs_f64(2.0));
        let res = Engine::new(
            spec.build(),
            SystemKind::Symphony.build(&models, gpus, Micros::ZERO),
            cfg,
        )
        .run();
        let late: u64 = res.metrics.per_model.iter().map(|pm| pm.late).sum();
        prop_assert!(late == 0, "deferred produced {late} late completions");
        Ok(())
    });
}

/// Same seed ⇒ bit-identical outcome counts (full determinism).
#[test]
fn prop_deterministic() {
    check("determinism", 16, |rng| {
        let models = random_models(rng);
        let gpus = 1 + rng.below(8) as usize;
        let rate = rng.range_f64(100.0, 3_000.0);
        let seed = rng.next_u64();
        let sys = all_systems()[rng.below(6) as usize];
        let run = || {
            let spec = WorkloadSpec::new(models.clone(), rate).seed(seed);
            let cfg = SimConfig::new(gpus, Micros::from_secs_f64(1.5)).seed(seed);
            let res =
                Engine::new(spec.build(), sys.build(&models, gpus, Micros::ZERO), cfg).run();
            res.metrics
                .per_model
                .iter()
                .map(|pm| (pm.good, pm.late, pm.dropped))
                .collect::<Vec<_>>()
        };
        prop_assert!(run() == run(), "non-deterministic run for {}", sys.label());
        Ok(())
    });
}

/// Symphony's min-id GPU rule consolidates: at light load, the highest
/// GPU ids do no work at all.
#[test]
fn prop_consolidation() {
    check("consolidation", 24, |rng| {
        let models = vec![model_zoo::resnet50_table2()];
        let gpus = 8;
        // Light load: well under one GPU's capacity.
        let rate = rng.range_f64(20.0, 120.0);
        let spec = WorkloadSpec::new(models.clone(), rate).seed(rng.next_u64());
        let cfg = SimConfig::new(gpus, Micros::from_secs_f64(3.0));
        let res = Engine::new(
            spec.build(),
            SystemKind::Symphony.build(&models, gpus, Micros::ZERO),
            cfg,
        )
        .run();
        let used = res.metrics.gpus_used();
        prop_assert!(used <= 2, "light load used {used} of {gpus} GPUs");
        Ok(())
    });
}

/// Batch sizes never exceed what the SLO admits: ℓ(b) ≤ SLO for every
/// executed batch, for every scheduler.
#[test]
fn prop_batches_fit_slo() {
    check("batches_fit_slo", default_cases(), |rng| {
        let models = random_models(rng);
        let gpus = 1 + rng.below(8) as usize;
        let rate = rng.range_f64(100.0, 5_000.0);
        let sys = all_systems()[rng.below(6) as usize];
        let spec = WorkloadSpec::new(models.clone(), rate).seed(rng.next_u64());
        let cfg = SimConfig::new(gpus, Micros::from_secs_f64(1.5)).trace(true);
        let res = Engine::new(spec.build(), sys.build(&models, gpus, Micros::ZERO), cfg).run();
        for t in &res.trace {
            let m = &models[t.model.0 as usize];
            prop_assert!(
                m.profile.latency(t.size) <= m.slo,
                "{}: batch {} of {} exceeds SLO",
                sys.label(),
                t.size,
                m.name
            );
        }
        Ok(())
    });
}

/// One batch observed at a backend channel during a coordinator run.
struct ExecObs {
    /// Batch size.
    n: u32,
    /// Dispatch timestamp on the coordinator clock.
    at: Micros,
    /// Earliest deadline among the batch's requests.
    min_deadline: Micros,
    /// The dispatching model's latency profile.
    profile: symphony::core::profile::LatencyProfile,
}

/// Spawn a loopback `rank-server` (one session, ephemeral port) for a
/// remote-tier drive; returns the address and the server thread.
fn spawn_loopback_rank_server(
    shards: usize,
    num_gpus: usize,
) -> (String, std::thread::JoinHandle<()>) {
    use symphony::net::server::{RankServer, RankServerConfig};
    let server = RankServer::bind(RankServerConfig {
        listen: "127.0.0.1:0".into(),
        shards,
        gpus: 0..num_gpus as u32,
        max_sessions: Some(1),
        busy_poll: false,
        pin_cores: false,
        fault_plan: symphony::net::faults::FaultPlan::none(),
        metrics_listen: None,
    })
    .expect("bind loopback rank server");
    let addr = server.local_addr().to_string();
    let h = std::thread::spawn(move || server.run().expect("rank server run"));
    (addr, h)
}

/// Drive a real (wall-clock) coordinator with a random bursty workload
/// and collect every dispatched batch per GPU channel. With `remote`,
/// the rank tier runs behind a loopback `rank-server` process boundary
/// (real framed TCP) instead of in-process channels.
fn drive_coordinator(rng: &mut Rng, rank_shards: usize, remote: bool) -> Vec<Vec<ExecObs>> {
    use std::sync::mpsc::channel;
    use std::time::Duration;
    use symphony::coordinator::{
        Completion, Coordinator, CoordinatorConfig, ToBackend,
    };
    use symphony::core::profile::LatencyProfile;
    use symphony::core::types::{ModelId, Request, RequestId};

    let n_models = 1 + rng.below(4) as usize;
    let num_gpus = 1 + rng.below(5) as usize;
    let profiles: Vec<LatencyProfile> = (0..n_models)
        .map(|_| LatencyProfile::new(rng.range_f64(0.1, 0.5), rng.range_f64(0.5, 2.0)))
        .collect();
    let slos: Vec<Micros> = (0..n_models)
        .map(|_| Micros::from_millis_f64(rng.range_f64(15.0, 30.0)))
        .collect();

    let mut backend_txs = Vec::new();
    let mut backend_rxs = Vec::new();
    for _ in 0..num_gpus {
        let (tx, rx) = channel::<ToBackend>();
        backend_txs.push(tx);
        backend_rxs.push(rx);
    }
    let (remote_ranks, server) = if remote {
        let (addr, h) = spawn_loopback_rank_server(rank_shards, num_gpus);
        (vec![addr], Some(h))
    } else {
        (Vec::new(), None)
    };
    let (comp_tx, _comp_rx) = channel::<Completion>();
    let coord = Coordinator::spawn(
        CoordinatorConfig {
            profiles: profiles.clone(),
            num_gpus,
            initial_gpus: None,
            rank_shards,
            ingest_shards: 1,
            model_workers: None,
            net_bound: Micros::from_millis_f64(1.0),
            exec_margin: Micros::ZERO,
            remote_ranks,
            busy_poll: false,
            pin_cores: false,
            reconnect: symphony::net::client::ReconnectPolicy::default(),
            fault_plan: symphony::net::faults::FaultPlan::none(),
        },
        backend_txs,
        comp_tx,
    );

    // Bursty submission for ~60ms: saturates the GPUs so sharded runs
    // exercise overflow steering.
    let mut id = 0u64;
    for _ in 0..(20 + rng.below(20)) {
        let burst = 1 + rng.below(8);
        for _ in 0..burst {
            let m = rng.below(n_models as u64) as usize;
            let now = coord.clock.now();
            coord.submit(Request {
                id: RequestId(id),
                model: ModelId(m as u32),
                arrival: now,
                deadline: now + slos[m],
            });
            id += 1;
        }
        std::thread::sleep(Duration::from_millis(1 + rng.below(3)));
    }
    // Drain: longest SLO plus margin so deferred windows fire.
    std::thread::sleep(Duration::from_millis(80));
    coord.shutdown();
    if let Some(h) = server {
        let _ = h.join();
    }

    backend_rxs
        .into_iter()
        .map(|rx| {
            let mut v: Vec<ExecObs> = rx
                .try_iter()
                .filter_map(|msg| match msg {
                    ToBackend::Execute {
                        model,
                        requests,
                        dispatched_at,
                    } => Some(ExecObs {
                        n: requests.len() as u32,
                        at: dispatched_at,
                        min_deadline: requests
                            .iter()
                            .map(|r| r.deadline)
                            .min()
                            .unwrap_or(Micros::MAX),
                        profile: profiles[model.0 as usize],
                    }),
                    _ => None,
                })
                .collect();
            v.sort_by_key(|e| e.at);
            v
        })
        .collect()
}

/// Window invariant, real coordinator, single-rank *and* sharded: no
/// dispatched batch can finish past the head deadline of its requests
/// (`dispatched_at + ℓ(b) ≤ min deadline`). This holds under any thread
/// interleaving because the ModelThread sizes the batch against the
/// head budget at dispatch time.
#[test]
fn prop_coordinator_window_invariant() {
    check("coordinator_window", 6, |rng| {
        for rank_shards in [1usize, 4] {
            let per_gpu = drive_coordinator(rng, rank_shards, false);
            for (g, execs) in per_gpu.iter().enumerate() {
                for e in execs {
                    prop_assert!(e.n > 0, "empty batch dispatched on gpu {g}");
                    let end = e.at + e.profile.latency(e.n);
                    prop_assert!(
                        end <= e.min_deadline,
                        "shards={rank_shards} gpu={g}: batch of {} dispatched at {:?} \
                         ends {:?} past head deadline {:?}",
                        e.n,
                        e.at,
                        end,
                        e.min_deadline
                    );
                }
            }
        }
        Ok(())
    });
}

/// Shard routing never grants the same GPU to two models concurrently:
/// each GPU's dispatched batches are strictly serialized — the next
/// dispatch starts at or after the previous one's busy estimate, for
/// both the single-rank and the sharded coordinator.
#[test]
fn prop_coordinator_no_double_grant() {
    check("coordinator_no_double_grant", 6, |rng| {
        for rank_shards in [1usize, 4] {
            let per_gpu = drive_coordinator(rng, rank_shards, false);
            for (g, execs) in per_gpu.iter().enumerate() {
                for w in execs.windows(2) {
                    let prev_busy_until = w[0].at + w[0].profile.latency(w[0].n);
                    prop_assert!(
                        w[1].at >= prev_busy_until,
                        "shards={rank_shards} gpu={g}: dispatch at {:?} overlaps \
                         previous batch busy until {:?}",
                        w[1].at,
                        prev_busy_until
                    );
                }
            }
        }
        Ok(())
    });
}

/// The schedulability invariants must survive the process boundary:
/// with the rank tier behind a loopback `rank-server` (real framed
/// TCP, `--remote-ranks` configuration), no dispatched batch may
/// finish past the head deadline of its requests, and each GPU's
/// dispatches stay strictly serialized. Safety is enforced client-side
/// (the worker re-plans the batch at grant time on its own clock), so
/// wire latency and handshake clock skew may cost batching quality but
/// never correctness — exactly what this property pins down.
#[test]
fn prop_remote_coordinator_window_and_serialization() {
    check("remote_coordinator_invariants", 4, |rng| {
        for rank_shards in [1usize, 3] {
            let per_gpu = drive_coordinator(rng, rank_shards, true);
            for (g, execs) in per_gpu.iter().enumerate() {
                for e in execs {
                    prop_assert!(e.n > 0, "remote: empty batch dispatched on gpu {g}");
                    let end = e.at + e.profile.latency(e.n);
                    prop_assert!(
                        end <= e.min_deadline,
                        "remote shards={rank_shards} gpu={g}: batch of {} at {:?} \
                         ends {:?} past head deadline {:?}",
                        e.n,
                        e.at,
                        end,
                        e.min_deadline
                    );
                }
                for w in execs.windows(2) {
                    let prev_busy_until = w[0].at + w[0].profile.latency(w[0].n);
                    prop_assert!(
                        w[1].at >= prev_busy_until,
                        "remote shards={rank_shards} gpu={g}: dispatch at {:?} \
                         overlaps previous batch busy until {:?}",
                        w[1].at,
                        prev_busy_until
                    );
                }
            }
        }
        Ok(())
    });
}

/// One resize event observed during a drive: when the drain of `gpu`
/// was acked (the GPU became provably idle and retired).
struct DrainObs {
    gpu: u32,
    acked_at: Micros,
}

/// Like `drive_coordinator`, but resizes the cluster mid-run through
/// the §3.5 drain/attach protocol: the run starts with only part of
/// the capacity attached, attaches the rest under load, then drains
/// from the top while submissions continue. Returns the per-GPU
/// dispatch observations plus the drain acks.
fn drive_coordinator_with_resize(
    rng: &mut symphony::util::rng::Rng,
    rank_shards: usize,
) -> (Vec<Vec<ExecObs>>, Vec<DrainObs>) {
    use std::sync::mpsc::channel;
    use std::time::Duration;
    use symphony::coordinator::{
        Completion, Coordinator, CoordinatorConfig, ToBackend,
    };
    use symphony::core::profile::LatencyProfile;
    use symphony::core::types::{GpuId, ModelId, Request, RequestId};

    let n_models = 1 + rng.below(4) as usize;
    let num_gpus = 2 + rng.below(4) as usize;
    let initial = 1 + rng.below(num_gpus as u64 - 1) as usize;
    let profiles: Vec<LatencyProfile> = (0..n_models)
        .map(|_| LatencyProfile::new(rng.range_f64(0.1, 0.5), rng.range_f64(0.5, 2.0)))
        .collect();
    let slos: Vec<Micros> = (0..n_models)
        .map(|_| Micros::from_millis_f64(rng.range_f64(15.0, 30.0)))
        .collect();

    let mut backend_txs = Vec::new();
    let mut backend_rxs = Vec::new();
    for _ in 0..num_gpus {
        let (tx, rx) = channel::<ToBackend>();
        backend_txs.push(tx);
        backend_rxs.push(rx);
    }
    let (comp_tx, _comp_rx) = channel::<Completion>();
    let coord = Coordinator::spawn(
        CoordinatorConfig {
            profiles: profiles.clone(),
            num_gpus,
            initial_gpus: Some(initial),
            rank_shards,
            ingest_shards: 1,
            model_workers: None,
            net_bound: Micros::from_millis_f64(1.0),
            exec_margin: Micros::ZERO,
            remote_ranks: Vec::new(),
            busy_poll: false,
            pin_cores: false,
            reconnect: symphony::net::client::ReconnectPolicy::default(),
            fault_plan: symphony::net::faults::FaultPlan::none(),
        },
        backend_txs,
        comp_tx,
    );
    let ctl = coord.cluster_ctl();
    let (ack_tx, ack_rx) = channel::<GpuId>();

    let mut id = 0u64;
    let mut submit_burst = |rng: &mut symphony::util::rng::Rng| {
        let burst = 1 + rng.below(8);
        for _ in 0..burst {
            let m = rng.below(n_models as u64) as usize;
            let now = coord.clock.now();
            coord.submit(Request {
                id: RequestId(id),
                model: ModelId(m as u32),
                arrival: now,
                deadline: now + slos[m],
            });
            id += 1;
        }
    };

    // Phase 1: saturate the initially attached prefix.
    for _ in 0..(6 + rng.below(6)) {
        submit_burst(rng);
        std::thread::sleep(Duration::from_millis(1 + rng.below(3)));
    }
    // Phase 2: attach the detached headroom under load (the add path).
    for g in initial..num_gpus {
        ctl.attach(GpuId(g as u32)).expect("attach");
        submit_burst(rng);
        std::thread::sleep(Duration::from_millis(1 + rng.below(3)));
    }
    // Phase 3: drain from the top — the consolidation retire order —
    // while submissions continue (mid-window resizes).
    let n_drain = 1 + rng.below(num_gpus as u64 - 1) as usize;
    let mut pending = Vec::new();
    for g in ((num_gpus - n_drain)..num_gpus).rev() {
        ctl.drain(GpuId(g as u32), ack_tx.clone()).expect("drain");
        pending.push(g as u32);
        submit_burst(rng);
        std::thread::sleep(Duration::from_millis(1 + rng.below(3)));
    }
    // Collect the acks; every drained GPU must eventually retire.
    let mut drains = Vec::new();
    for _ in 0..pending.len() {
        let gpu = ack_rx
            .recv_timeout(Duration::from_millis(2_000))
            .expect("drain must ack once in-flight work completes");
        drains.push(DrainObs {
            gpu: gpu.0,
            acked_at: coord.clock.now(),
        });
    }
    // Phase 4: keep the load coming on the shrunken cluster.
    for _ in 0..(6 + rng.below(6)) {
        submit_burst(rng);
        std::thread::sleep(Duration::from_millis(1 + rng.below(3)));
    }
    std::thread::sleep(Duration::from_millis(80));
    coord.shutdown();

    let per_gpu = backend_rxs
        .into_iter()
        .map(|rx| {
            let mut v: Vec<ExecObs> = rx
                .try_iter()
                .filter_map(|msg| match msg {
                    ToBackend::Execute {
                        model,
                        requests,
                        dispatched_at,
                    } => Some(ExecObs {
                        n: requests.len() as u32,
                        at: dispatched_at,
                        min_deadline: requests
                            .iter()
                            .map(|r| r.deadline)
                            .min()
                            .unwrap_or(Micros::MAX),
                        profile: profiles[model.0 as usize],
                    }),
                    _ => None,
                })
                .collect();
            v.sort_by_key(|e| e.at);
            v
        })
        .collect();
    (per_gpu, drains)
}

/// The §3.5 drain/retire property: once a `Drain(gpu)` is acked the
/// GPU is retired — no later dispatch may ever name it — and resizing
/// mid-window never breaks the window invariant (no batch finishes
/// past its head deadline) or double-books a GPU. Single-rank and
/// sharded.
#[test]
fn prop_no_grant_after_drain_across_resize() {
    check("drain_retire", 6, |rng| {
        for rank_shards in [1usize, 3] {
            let (per_gpu, drains) = drive_coordinator_with_resize(rng, rank_shards);
            prop_assert!(!drains.is_empty(), "driver always drains something");
            for d in &drains {
                for e in &per_gpu[d.gpu as usize] {
                    prop_assert!(
                        e.at <= d.acked_at,
                        "shards={rank_shards} gpu={}: dispatched at {:?}, after \
                         its drain was acked at {:?}",
                        d.gpu,
                        e.at,
                        d.acked_at
                    );
                }
            }
            // Resize events must not weaken the schedulability
            // invariants that hold for a fixed cluster.
            for (g, execs) in per_gpu.iter().enumerate() {
                for e in execs {
                    prop_assert!(e.n > 0, "empty batch dispatched on gpu {g}");
                    let end = e.at + e.profile.latency(e.n);
                    prop_assert!(
                        end <= e.min_deadline,
                        "shards={rank_shards} gpu={g}: batch of {} at {:?} ends \
                         {:?} past head deadline {:?} across resize",
                        e.n,
                        e.at,
                        end,
                        e.min_deadline
                    );
                }
                for w in execs.windows(2) {
                    let prev_busy_until = w[0].at + w[0].profile.latency(w[0].n);
                    prop_assert!(
                        w[1].at >= prev_busy_until,
                        "shards={rank_shards} gpu={g}: dispatch at {:?} overlaps \
                         previous batch busy until {:?} across resize",
                        w[1].at,
                        prev_busy_until
                    );
                }
            }
        }
        Ok(())
    });
}

/// Under Gamma(0.1) burstiness the deferred scheduler still satisfies
/// its feasibility discipline at low rates (sanity under the paper's
/// harshest arrival pattern).
#[test]
fn prop_bursty_low_load_clean() {
    check("bursty_low_load", 24, |rng| {
        let models = model_zoo::resnet_like_variants(4, 50.0, GpuKind::Gtx1080Ti);
        let spec = WorkloadSpec::new(models.clone(), 200.0)
            .gamma_shape(0.1)
            .seed(rng.next_u64());
        let cfg = SimConfig::new(8, Micros::from_secs_f64(3.0));
        let res = Engine::new(
            spec.build(),
            SystemKind::Symphony.build(&models, 8, Micros::ZERO),
            cfg,
        )
        .run();
        let bad = res.metrics.bad_fraction();
        prop_assert!(bad < 0.05, "bad fraction {bad} at light bursty load");
        Ok(())
    });
}
