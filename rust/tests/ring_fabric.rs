//! Stress tests for the lock-free shard fabric (`util::ring`): the
//! MPSC ring the coordinator's submit→grant hops ride after PR 7.
//!
//! These run in the nightly TSan/ASan matrix (see ci.yml) — the point
//! is to give the sanitizers real producer/consumer interleavings to
//! chew on, not just the single-threaded unit tests in `ring.rs`:
//!
//! * no loss, no duplication — every tagged message arrives exactly
//!   once (multiset equality against what producers sent);
//! * FIFO per producer — a consumer never sees producer P's message k
//!   after its message k+1;
//! * both documented full-queue policies — `try_send` sheds (and the
//!   shed count balances the books), blocking `send` never drops;
//! * wrap-around — slot sequence-lap arithmetic stays correct across
//!   many laps of a tiny ring;
//! * `Parker` wake-not-lost — the Dekker prepare/re-check/park
//!   protocol never strands the consumer when the producer publishes
//!   between the re-check and the park.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use symphony::util::ring::{ring, Parker, TryRecvError, TrySendError};

/// Tag a message with its producer and per-producer sequence number so
/// the consumer can check ordering and uniqueness.
fn tag(producer: u64, seq: u64) -> u64 {
    (producer << 32) | seq
}

fn untag(v: u64) -> (u64, u64) {
    (v >> 32, v & 0xffff_ffff)
}

/// N producers blast tagged messages through a ring smaller than the
/// total volume, using the control-traffic policy (blocking `send`,
/// must not drop). The consumer asserts exactly-once delivery and
/// per-producer FIFO.
#[test]
fn mpsc_stress_no_loss_no_dup_fifo_per_producer() {
    const PRODUCERS: u64 = 4;
    const PER_PRODUCER: u64 = 20_000;

    let (tx, rx) = ring::<u64>(256);
    let mut handles = Vec::new();
    for p in 0..PRODUCERS {
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || {
            for seq in 0..PER_PRODUCER {
                tx.send(tag(p, seq)).expect("consumer alive for whole run");
            }
        }));
    }
    drop(tx); // consumer sees Disconnected once all producers finish

    let consumer = std::thread::spawn(move || {
        let mut next_seq = [0u64; PRODUCERS as usize];
        let mut total = 0u64;
        while let Ok(v) = rx.recv() {
            let (p, seq) = untag(v);
            assert_eq!(
                seq, next_seq[p as usize],
                "producer {p} out of order: got seq {seq}, expected {}",
                next_seq[p as usize]
            );
            next_seq[p as usize] = seq + 1;
            total += 1;
        }
        (total, next_seq)
    });

    for h in handles {
        h.join().expect("producer");
    }
    let (total, next_seq) = consumer.join().expect("consumer");
    assert_eq!(total, PRODUCERS * PER_PRODUCER, "no loss, no duplication");
    for (p, n) in next_seq.iter().enumerate() {
        assert_eq!(*n, PER_PRODUCER, "producer {p} fully delivered");
    }
}

/// The request-traffic policy: `try_send` against a full ring sheds,
/// and the books balance — delivered + shed == sent, with delivered
/// messages still unique and FIFO per producer (shedding drops
/// messages, it never reorders or duplicates them).
#[test]
fn try_send_shed_policy_balances_and_keeps_order() {
    const PRODUCERS: u64 = 3;
    const PER_PRODUCER: u64 = 30_000;

    let (tx, rx) = ring::<u64>(64);
    let shed = Arc::new(AtomicU64::new(0));
    let mut handles = Vec::new();
    for p in 0..PRODUCERS {
        let tx = tx.clone();
        let shed = shed.clone();
        handles.push(std::thread::spawn(move || {
            for seq in 0..PER_PRODUCER {
                match tx.try_send(tag(p, seq)) {
                    Ok(()) => {}
                    Err(TrySendError::Full(_)) => {
                        // The ingest shed point: count and move on.
                        shed.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(TrySendError::Disconnected(_)) => {
                        panic!("consumer alive for whole run")
                    }
                }
            }
        }));
    }
    drop(tx);

    let consumer = std::thread::spawn(move || {
        let mut last_seq = [None::<u64>; PRODUCERS as usize];
        let mut delivered = 0u64;
        while let Ok(v) = rx.recv() {
            let (p, seq) = untag(v);
            if let Some(prev) = last_seq[p as usize] {
                assert!(
                    seq > prev,
                    "producer {p}: seq {seq} after {prev} — duplicate or reorder"
                );
            }
            last_seq[p as usize] = Some(seq);
            delivered += 1;
        }
        delivered
    });

    for h in handles {
        h.join().expect("producer");
    }
    let delivered = consumer.join().expect("consumer");
    assert_eq!(
        delivered + shed.load(Ordering::Relaxed),
        PRODUCERS * PER_PRODUCER,
        "every message either delivered or counted as shed"
    );
}

/// Sequence-lap arithmetic across many wrap-arounds of a tiny ring:
/// fill to capacity, observe `Full`, drain, refill — hundreds of laps.
#[test]
fn wrap_around_at_capacity_boundary() {
    let (tx, rx) = ring::<u64>(4);
    assert_eq!(rx.capacity(), 4);

    let mut next = 0u64;
    for lap in 0..300u64 {
        // Fill to the brim, confirm the ring reports Full (not a lost
        // message, not an overwrite).
        for _ in 0..4 {
            tx.try_send(next).expect("room below capacity");
            next += 1;
        }
        match tx.try_send(u64::MAX) {
            Err(TrySendError::Full(v)) => assert_eq!(v, u64::MAX, "shed value comes back"),
            other => panic!("lap {lap}: expected Full, got {other:?}"),
        }
        // Partial drain + refill so head/tail cross the boundary at
        // every alignment, not just multiples of the capacity.
        for _ in 0..2 {
            let got = rx.try_recv().expect("published value");
            assert_eq!(got, next - 4, "FIFO across wrap");
            tx.try_send(next).expect("slot just freed");
            next += 1;
        }
        // Drain the remaining window back to empty.
        let mut expect = next - 4;
        for _ in 0..4 {
            assert_eq!(rx.try_recv(), Ok(expect));
            expect += 1;
        }
        assert_eq!(rx.try_recv(), Err(TryRecvError::Empty));
    }
}

/// Blocking `send` (control traffic) parks against a full ring and
/// completes once the consumer frees a slot — it must not drop and
/// must not error while the consumer is merely slow.
#[test]
fn blocking_send_waits_out_a_full_ring() {
    let (tx, rx) = ring::<u64>(2);
    tx.try_send(1).unwrap();
    tx.try_send(2).unwrap();

    let sender = std::thread::spawn(move || {
        let t0 = Instant::now();
        tx.send(3).expect("consumer drains before SEND_RETRY_BOUND");
        t0.elapsed()
    });

    std::thread::sleep(Duration::from_millis(50));
    assert_eq!(rx.try_recv(), Ok(1));
    let waited = sender.join().expect("sender");
    assert!(
        waited >= Duration::from_millis(40),
        "send should have blocked on the full ring, returned after {waited:?}"
    );
    assert_eq!(rx.recv_timeout(Duration::from_secs(1)), Ok(2));
    assert_eq!(rx.recv_timeout(Duration::from_secs(1)), Ok(3));
}

/// `drain_into` honors its `max` and preserves FIFO across calls.
#[test]
fn drain_into_bounded_batches_stay_fifo() {
    let (tx, rx) = ring::<u64>(16);
    for i in 0..10 {
        tx.try_send(i).unwrap();
    }
    let mut out = Vec::new();
    assert_eq!(rx.drain_into(&mut out, 4), 4);
    assert_eq!(rx.drain_into(&mut out, 4), 4);
    assert_eq!(rx.drain_into(&mut out, 4), 2);
    assert_eq!(rx.drain_into(&mut out, 4), 0);
    assert_eq!(out, (0..10).collect::<Vec<_>>());
}

/// The Dekker wake-not-lost protocol, hammered directly: the producer
/// publishes (atomic store) then `wake()`s; the consumer `prepare()`s,
/// re-checks, and only parks if the publish is not yet visible. If a
/// wake were ever lost, an iteration would stall until its park
/// deadline — the generous per-iteration deadline converts "lost
/// wakeup" into a loud assertion instead of a hang.
#[test]
fn parker_wake_is_never_lost() {
    const ITERS: u64 = 20_000;
    let parker = Arc::new(Parker::new());
    let turn = Arc::new(AtomicU64::new(0));

    let producer = {
        let parker = parker.clone();
        let turn = turn.clone();
        std::thread::spawn(move || {
            for i in 1..=ITERS {
                turn.store(i, Ordering::SeqCst);
                parker.wake();
                // Vary the interleaving: sometimes race straight into
                // the next publish, sometimes let the consumer park.
                if i % 64 == 0 {
                    std::thread::sleep(Duration::from_micros(50));
                } else if i % 7 == 0 {
                    std::thread::yield_now();
                }
            }
        })
    };

    let t0 = Instant::now();
    for i in 1..=ITERS {
        loop {
            parker.prepare();
            if turn.load(Ordering::SeqCst) >= i {
                parker.cancel();
                break;
            }
            // A lost wake would burn the full deadline here; the outer
            // assertion below catches systematic loss without making a
            // single spurious timeout fatal.
            parker.park(Some(Instant::now() + Duration::from_millis(100)));
        }
    }
    producer.join().expect("producer");
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "consumer progress stalled — wakeups are being lost"
    );
}

/// The ring's own park edge under a bursty producer: the consumer uses
/// blocking `recv` (spin → yield → park) and a producer that
/// alternates bursts with idle gaps long enough to force real parking.
/// Everything sent must arrive, in order.
#[test]
fn ring_recv_parks_and_never_misses_a_burst() {
    const BURSTS: u64 = 40;
    const PER_BURST: u64 = 100;

    let (tx, rx) = ring::<u64>(512);
    let producer = std::thread::spawn(move || {
        let mut v = 0u64;
        for _ in 0..BURSTS {
            for _ in 0..PER_BURST {
                tx.send(v).expect("consumer alive");
                v += 1;
            }
            // Long enough for the consumer's Waiter ladder to exhaust
            // its spin+yield budget and genuinely park.
            std::thread::sleep(Duration::from_millis(2));
        }
    });

    let mut expect = 0u64;
    while let Ok(v) = rx.recv() {
        assert_eq!(v, expect);
        expect += 1;
    }
    producer.join().expect("producer");
    assert_eq!(expect, BURSTS * PER_BURST);
}

/// Busy-poll and parked receivers are observationally identical: the
/// same tagged multi-producer workload delivers the same per-producer
/// sequences either way (the `--busy-poll` flag trades CPU for
/// latency, never correctness).
#[test]
fn busy_poll_and_parked_drains_deliver_identically() {
    fn run(busy_poll: bool) -> Vec<u64> {
        const PRODUCERS: u64 = 3;
        const PER_PRODUCER: u64 = 5_000;
        let (tx, rx) = ring::<u64>(256);
        rx.set_busy_poll(busy_poll);
        let mut handles = Vec::new();
        for p in 0..PRODUCERS {
            let tx = tx.clone();
            handles.push(std::thread::spawn(move || {
                for seq in 0..PER_PRODUCER {
                    tx.send(tag(p, seq)).expect("consumer alive");
                }
            }));
        }
        drop(tx);
        // Per-producer delivery orders (global interleaving is
        // scheduler-dependent; per-producer sequences are the contract).
        let mut seqs: Vec<Vec<u64>> = vec![Vec::new(); PRODUCERS as usize];
        while let Ok(v) = rx.recv() {
            let (p, seq) = untag(v);
            seqs[p as usize].push(seq);
        }
        for h in handles {
            h.join().expect("producer");
        }
        seqs.into_iter().flatten().collect()
    }

    let parked = run(false);
    let spinning = run(true);
    assert_eq!(parked, spinning, "drain mode must not change delivery");
}

/// Dropping the receiver turns both send flavors into immediate
/// `Disconnected`/`SendError` under concurrency — producers must not
/// spin out the full retry bound against a dead consumer.
#[test]
fn producers_observe_receiver_death_promptly() {
    let (tx, rx) = ring::<u64>(8);
    let gate = Arc::new(AtomicUsize::new(0));
    let mut handles = Vec::new();
    for _ in 0..3 {
        let tx = tx.clone();
        let gate = gate.clone();
        handles.push(std::thread::spawn(move || {
            while gate.load(Ordering::Acquire) == 0 {
                std::hint::spin_loop();
            }
            let t0 = Instant::now();
            let mut saw_disconnect = false;
            for i in 0..1_000u64 {
                if tx.send(i).is_err() {
                    saw_disconnect = true;
                    break;
                }
            }
            assert!(saw_disconnect, "send kept succeeding with no receiver");
            assert!(
                t0.elapsed() < Duration::from_secs(4),
                "disconnect must surface well before SEND_RETRY_BOUND"
            );
        }));
    }
    drop(rx);
    gate.store(1, Ordering::Release);
    for h in handles {
        h.join().expect("producer");
    }
}
