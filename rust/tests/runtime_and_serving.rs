//! Integration over the PJRT runtime + real-time serving stack. PJRT
//! tests skip gracefully when `artifacts/` has not been built (`make
//! artifacts`); the sleep-backend tests always run.

use std::time::Duration;

use symphony::core::profile::ModelSpec;
use symphony::runtime::{default_artifacts_dir, ModelRuntime, NUM_CLASSES};
use symphony::serve::{serve, BackendKind, ServeConfig};

#[test]
fn sleep_backend_meets_slo_at_moderate_load() {
    let models = vec![
        ModelSpec::new("a", 0.2, 2.0, 60.0),
        ModelSpec::new("b", 0.2, 2.0, 60.0),
        ModelSpec::new("c", 0.2, 2.0, 60.0),
    ];
    let report = serve(ServeConfig {
        models,
        num_gpus: 3,
        initial_gpus: None,
        rank_shards: 1,
        ingest_shards: 1,
        model_workers: None,
        remote_ranks: Vec::new(),
        total_rate: 300.0,
        rate_phases: Vec::new(),
        duration: Duration::from_millis(800),
        backend: BackendKind::Sleep,
        autoscale: None,
        busy_poll: false,
        pin_cores: false,
        seed: 11,
        fault_plan: symphony::net::faults::FaultPlan::none(),
        trace_sample: 0,
        trace_out: None,
        metrics_listen: None,
    })
    .unwrap();
    assert!(report.submitted > 150);
    assert!(report.bad_fraction() < 0.1, "bad {}", report.bad_fraction());
    assert!(report.median_batch >= 1);
}

#[test]
fn sleep_backend_batches_under_pressure() {
    // One GPU, high rate: the coordinator must batch to survive.
    let models = vec![ModelSpec::new("a", 0.5, 5.0, 80.0)];
    let report = serve(ServeConfig {
        models,
        num_gpus: 1,
        initial_gpus: None,
        rank_shards: 1,
        ingest_shards: 1,
        model_workers: None,
        remote_ranks: Vec::new(),
        total_rate: 400.0,
        rate_phases: Vec::new(),
        duration: Duration::from_millis(700),
        backend: BackendKind::Sleep,
        autoscale: None,
        busy_poll: false,
        pin_cores: false,
        seed: 3,
        fault_plan: symphony::net::faults::FaultPlan::none(),
        trace_sample: 0,
        trace_out: None,
        metrics_listen: None,
    })
    .unwrap();
    assert!(
        report.mean_batch >= 4.0,
        "mean batch {} too small under pressure",
        report.mean_batch
    );
}

#[test]
fn pjrt_runtime_numerics() {
    let Some(dir) = default_artifacts_dir() else {
        eprintln!("skipping pjrt test: artifacts/ not built");
        return;
    };
    let rt = ModelRuntime::load(&dir).expect("load");
    // Batch padding: executing n=5 uses the b=8 executable but returns
    // exactly 5 rows.
    let n = 5;
    let len = n * 32 * 32 * 3;
    // Structured (non-constant) inputs — constant images can die in the
    // zero-bias ReLUs and make every class equally likely.
    let inputs: Vec<f32> = (0..len).map(|i| ((i as f32) * 0.37).sin()).collect();
    let out = rt.execute(n as u32, &inputs).unwrap();
    assert_eq!(out.len(), n * NUM_CLASSES);
    for row in out.chunks(NUM_CLASSES) {
        let sum: f32 = row.iter().sum();
        assert!((sum - 1.0).abs() < 1e-3);
    }
    // Different inputs give different outputs (the network isn't
    // degenerate).
    let inputs2: Vec<f32> = (0..len).map(|i| ((i as f32) * 0.11).cos()).collect();
    let out2 = rt.execute(n as u32, &inputs2).unwrap();
    let diff: f32 = out
        .iter()
        .zip(&out2)
        .map(|(a, b)| (a - b).abs())
        .sum::<f32>();
    assert!(diff > 1e-4, "outputs identical for different inputs");
}

#[test]
fn pjrt_end_to_end_serving() {
    let Some(dir) = default_artifacts_dir() else {
        eprintln!("skipping pjrt serving test: artifacts/ not built");
        return;
    };
    // Use the measured CPU profile for scheduling so windows are honest.
    let rt = ModelRuntime::load(&dir).expect("load");
    let p = rt
        .profile
        .as_ref()
        .map(|m| m.fitted)
        .unwrap_or(symphony::core::profile::LatencyProfile::new(0.05, 0.2));
    drop(rt);
    let mut model = ModelSpec::new("tinycnn", p.alpha_ms.max(0.02), p.beta_ms.max(0.05), 60.0);
    model.profile =
        symphony::core::profile::LatencyProfile::new(p.alpha_ms.max(0.02), p.beta_ms.max(0.05));
    let report = serve(ServeConfig {
        models: vec![model],
        num_gpus: 1,
        initial_gpus: None,
        rank_shards: 1,
        ingest_shards: 1,
        model_workers: None,
        remote_ranks: Vec::new(),
        total_rate: 150.0,
        rate_phases: Vec::new(),
        duration: Duration::from_millis(700),
        backend: BackendKind::Pjrt {
            artifacts_dir: dir,
        },
        autoscale: None,
        busy_poll: false,
        pin_cores: false,
        seed: 9,
        fault_plan: symphony::net::faults::FaultPlan::none(),
        trace_sample: 0,
        trace_out: None,
        metrics_listen: None,
    })
    .unwrap();
    assert!(report.submitted > 60, "submitted {}", report.submitted);
    assert!(
        report.completed + report.dropped >= report.submitted / 2,
        "too few finished: {report:?}"
    );
    assert!(
        report.bad_fraction() < 0.2,
        "bad fraction {} on real model",
        report.bad_fraction()
    );
}
